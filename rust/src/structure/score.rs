//! Decomposable structure scores (BIC / log-likelihood) with a sharded,
//! read-mostly family-score cache over the shared counting substrate —
//! the backbone of score-based structure learning, and the baseline
//! family the constraint-based PC algorithm is compared against in every
//! structure-learning evaluation.
//!
//! Family counts come from [`crate::counts::CountCache`] (grouped
//! column-major counting, exact subset projection from cached superset
//! tables), so a hill-climbing run shares tables across candidate moves
//! — deleting a parent projects the smaller family table out of the
//! already-counted larger one — and, when the cache is shared with a
//! preceding PC run, across learning phases. Scores are memoized in
//! per-shard `RwLock` maps: the parallel candidate scan of
//! [`super::hill_climb`] re-probes the same families from many workers,
//! so reads must not serialize (the old single global `Mutex<HashMap>`
//! did exactly that).

use crate::core::{Dataset, VarId};
use crate::counts::{CountCache, CountCacheStats};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::RwLock;

/// Which decomposable score to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScoreKind {
    /// Bayesian information criterion: `LL - (ln N / 2) * params`.
    #[default]
    Bic,
    /// Akaike information criterion: `LL - params`.
    Aic,
    /// Pure maximum log-likelihood (no complexity penalty — overfits;
    /// useful for diagnostics).
    LogLikelihood,
}

/// Score-cache shards. Sized like the count cache's: enough to keep the
/// hill-climbing workers' write collisions rare, read locks are shared
/// anyway.
const SCORE_SHARDS: usize = 16;

/// A count cache the scorer either owns (the default — every scorer
/// routes through the substrate) or borrows (a learning pipeline sharing
/// one cache across CI tests, scoring and MLE).
enum CacheRef<'d> {
    Owned(Box<CountCache>),
    Shared(&'d CountCache),
}

impl CacheRef<'_> {
    fn get(&self) -> &CountCache {
        match self {
            CacheRef::Owned(c) => c,
            CacheRef::Shared(c) => c,
        }
    }
}

/// One score shard: `(var, sorted parents) -> family score`.
type ScoreShard = RwLock<HashMap<(VarId, Vec<VarId>), f64>>;

/// Family-decomposable scorer with memoization: `score(G) = Σ_v
/// family_score(v, pa_G(v))`, so local search only re-scores the
/// families an operation touches. `Sync`: the parallel hill-climbing
/// candidate scan shares one scorer across workers.
pub struct Scorer<'d> {
    data: &'d Dataset,
    pub kind: ScoreKind,
    /// Sharded read-mostly family-score memo.
    shards: Vec<ScoreShard>,
    counts: CacheRef<'d>,
    ln_n: f64,
}

impl<'d> Scorer<'d> {
    pub fn new(data: &'d Dataset, kind: ScoreKind) -> Self {
        Self::build(data, kind, CacheRef::Owned(Box::new(CountCache::new())))
    }

    /// Scorer drawing counts from a shared cache (e.g. one populated by
    /// a preceding PC run over the same dataset).
    pub fn with_cache(data: &'d Dataset, kind: ScoreKind, cache: &'d CountCache) -> Self {
        Self::build(data, kind, CacheRef::Shared(cache))
    }

    fn build(data: &'d Dataset, kind: ScoreKind, counts: CacheRef<'d>) -> Self {
        Scorer {
            data,
            kind,
            shards: (0..SCORE_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            counts,
            ln_n: (data.n_rows().max(1) as f64).ln(),
        }
    }

    fn shard_of(&self, v: VarId, parents: &[VarId]) -> usize {
        let mut h = DefaultHasher::new();
        v.hash(&mut h);
        parents.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Score of one family (memoized; read-mostly sharded lookup).
    pub fn family_score(&self, v: VarId, parents: &[VarId]) -> f64 {
        debug_assert!(parents.windows(2).all(|w| w[0] < w[1]));
        let shard = &self.shards[self.shard_of(v, parents)];
        if let Some(&s) = shard.read().unwrap().get(&(v, parents.to_vec())) {
            return s;
        }
        let s = self.compute_family(v, parents);
        // Racing computes insert the same deterministic value.
        shard.write().unwrap().insert((v, parents.to_vec()), s);
        s
    }

    fn compute_family(&self, v: VarId, parents: &[VarId]) -> f64 {
        // Family counts in (parent config, child state) layout, child
        // fastest — drawn from the substrate (cache hit, superset
        // projection, or one streaming pass) and scattered exactly.
        let mut key: Vec<VarId> = parents.to_vec();
        key.push(v);
        key.sort_unstable();
        let table = self.counts.get().table(self.data, &key);
        let mut order: Vec<VarId> = parents.to_vec();
        order.push(v);
        let counts = table.permuted_counts(&order);
        let card = self.data.cardinality(v);
        let n_cfg = counts.len() / card;
        let mut ll = 0.0;
        for cfg in 0..n_cfg {
            let row = &counts[cfg * card..(cfg + 1) * card];
            let total: u64 = row.iter().sum();
            if total == 0 {
                continue;
            }
            let tf = total as f64;
            for &c in row {
                if c > 0 {
                    let cf = c as f64;
                    ll += cf * (cf / tf).ln();
                }
            }
        }
        let params = (n_cfg * (card - 1)) as f64;
        match self.kind {
            ScoreKind::Bic => ll - 0.5 * self.ln_n * params,
            ScoreKind::Aic => ll - params,
            ScoreKind::LogLikelihood => ll,
        }
    }

    /// Total score of a DAG.
    pub fn dag_score(&self, dag: &crate::graph::Dag) -> f64 {
        (0..self.data.n_vars())
            .map(|v| self.family_score(v, dag.parents(v)))
            .sum()
    }

    /// Memoized family-score count (diagnostics).
    pub fn cached_families(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// Counting-substrate counters behind this scorer (hit rate, bytes).
    pub fn count_stats(&self) -> CountCacheStats {
        self.counts.get().stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Dag;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    fn data() -> Dataset {
        let net = repository::cancer();
        let mut rng = Pcg::seed_from(3);
        forward_sample_dataset(&net, 10_000, &mut rng)
    }

    #[test]
    fn true_structure_beats_empty_and_inverted() {
        let net = repository::cancer();
        let data = {
            let mut rng = Pcg::seed_from(3);
            forward_sample_dataset(&net, 10_000, &mut rng)
        };
        let scorer = Scorer::new(&data, ScoreKind::Bic);
        let truth = scorer.dag_score(net.dag());
        let empty = scorer.dag_score(&Dag::new(net.n_vars()));
        assert!(truth > empty, "true {truth} vs empty {empty}");
    }

    #[test]
    fn ll_monotone_in_parents_bic_not() {
        let data = data();
        let ll = Scorer::new(&data, ScoreKind::LogLikelihood);
        // Adding any parent never decreases LL.
        let base = ll.family_score(4, &[]);
        let with_p = ll.family_score(4, &[2]);
        let with_pp = ll.family_score(4, &[1, 2]);
        assert!(with_p >= base - 1e-9);
        assert!(with_pp >= with_p - 1e-9);
        // BIC penalizes the irrelevant parent 1 (dyspnoea ⟂ smoker | cancer).
        let bic = Scorer::new(&data, ScoreKind::Bic);
        assert!(bic.family_score(4, &[2]) > bic.family_score(4, &[1, 2]));
    }

    #[test]
    fn cache_hits() {
        let data = data();
        let s = Scorer::new(&data, ScoreKind::Bic);
        let a = s.family_score(0, &[1]);
        let b = s.family_score(0, &[1]);
        assert_eq!(a, b);
        assert_eq!(s.cached_families(), 1);
        // The count substrate saw exactly one table request.
        assert_eq!(s.count_stats().lookups(), 1);
    }

    #[test]
    fn shared_cache_scores_bit_identical() {
        // A scorer over a shared (possibly pre-warmed) count cache must
        // produce bit-identical scores to a fresh one.
        let data = data();
        let cache = CountCache::new();
        // Pre-warm with a superset table so some families project.
        cache.table(&data, &[0, 1, 2, 4]);
        let fresh = Scorer::new(&data, ScoreKind::Bic);
        let shared = Scorer::with_cache(&data, ScoreKind::Bic, &cache);
        for (v, ps) in [
            (0usize, vec![]),
            (2, vec![0, 1]),
            (4, vec![2]),
            (4, vec![1, 2]),
            (1, vec![0]),
        ] {
            let a = fresh.family_score(v, &ps);
            let b = shared.family_score(v, &ps);
            assert_eq!(a.to_bits(), b.to_bits(), "family ({v}, {ps:?})");
        }
        assert!(cache.stats().projections > 0, "{:?}", cache.stats());
    }

    #[test]
    fn concurrent_scoring_consistent() {
        // The sharded scorer is Sync: concurrent probes of overlapping
        // families agree with a sequential pass.
        let data = data();
        let scorer = Scorer::new(&data, ScoreKind::Bic);
        let expect: Vec<f64> =
            (0..data.n_vars()).map(|v| scorer.family_score(v, &[])).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for v in 0..data.n_vars() {
                        let s = scorer.family_score(v, &[]);
                        assert_eq!(s.to_bits(), expect[v].to_bits());
                    }
                });
            }
        });
    }

    #[test]
    fn score_kinds_ordering() {
        let data = data();
        // For the same family, LL >= AIC >= BIC (penalties grow).
        let v = 2;
        let ps = &[0usize, 1][..];
        let ll = Scorer::new(&data, ScoreKind::LogLikelihood).family_score(v, ps);
        let aic = Scorer::new(&data, ScoreKind::Aic).family_score(v, ps);
        let bic = Scorer::new(&data, ScoreKind::Bic).family_score(v, ps);
        assert!(ll >= aic && aic >= bic);
    }
}
