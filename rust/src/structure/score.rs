//! Decomposable structure scores (BIC / log-likelihood) with a family
//! score cache — the substrate for score-based structure learning, and
//! the baseline family the constraint-based PC algorithm is compared
//! against in every structure-learning evaluation.

use crate::core::{Dataset, VarId};
use crate::parameter::count_family;
use std::collections::HashMap;
use std::sync::Mutex;

/// Which decomposable score to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScoreKind {
    /// Bayesian information criterion: `LL - (ln N / 2) * params`.
    #[default]
    Bic,
    /// Akaike information criterion: `LL - params`.
    Aic,
    /// Pure maximum log-likelihood (no complexity penalty — overfits;
    /// useful for diagnostics).
    LogLikelihood,
}

/// Family-decomposable scorer with memoization: `score(G) = Σ_v
/// family_score(v, pa_G(v))`, so local search only re-scores the families
/// an operation touches.
pub struct Scorer<'d> {
    data: &'d Dataset,
    pub kind: ScoreKind,
    /// `(var, sorted parents) -> family score`. Mutex (not RwLock): the
    /// critical section is a hash probe, contention is negligible
    /// relative to counting.
    cache: Mutex<HashMap<(VarId, Vec<VarId>), f64>>,
    ln_n: f64,
}

impl<'d> Scorer<'d> {
    pub fn new(data: &'d Dataset, kind: ScoreKind) -> Self {
        Scorer {
            data,
            kind,
            cache: Mutex::new(HashMap::new()),
            ln_n: (data.n_rows().max(1) as f64).ln(),
        }
    }

    /// Score of one family (memoized).
    pub fn family_score(&self, v: VarId, parents: &[VarId]) -> f64 {
        debug_assert!(parents.windows(2).all(|w| w[0] < w[1]));
        let key = (v, parents.to_vec());
        if let Some(&s) = self.cache.lock().unwrap().get(&key) {
            return s;
        }
        let s = self.compute_family(v, parents);
        self.cache.lock().unwrap().insert(key, s);
        s
    }

    fn compute_family(&self, v: VarId, parents: &[VarId]) -> f64 {
        let counts = count_family(self.data, v, parents);
        let card = counts.card;
        let n_cfg = counts.counts.len() / card;
        let mut ll = 0.0;
        for cfg in 0..n_cfg {
            let row = &counts.counts[cfg * card..(cfg + 1) * card];
            let total: u64 = row.iter().sum();
            if total == 0 {
                continue;
            }
            let tf = total as f64;
            for &c in row {
                if c > 0 {
                    let cf = c as f64;
                    ll += cf * (cf / tf).ln();
                }
            }
        }
        let params = (n_cfg * (card - 1)) as f64;
        match self.kind {
            ScoreKind::Bic => ll - 0.5 * self.ln_n * params,
            ScoreKind::Aic => ll - params,
            ScoreKind::LogLikelihood => ll,
        }
    }

    /// Total score of a DAG.
    pub fn dag_score(&self, dag: &crate::graph::Dag) -> f64 {
        (0..self.data.n_vars())
            .map(|v| self.family_score(v, dag.parents(v)))
            .sum()
    }

    /// Cache size (diagnostics).
    pub fn cached_families(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Dag;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    fn data() -> Dataset {
        let net = repository::cancer();
        let mut rng = Pcg::seed_from(3);
        forward_sample_dataset(&net, 10_000, &mut rng)
    }

    #[test]
    fn true_structure_beats_empty_and_inverted() {
        let net = repository::cancer();
        let data = {
            let mut rng = Pcg::seed_from(3);
            forward_sample_dataset(&net, 10_000, &mut rng)
        };
        let scorer = Scorer::new(&data, ScoreKind::Bic);
        let truth = scorer.dag_score(net.dag());
        let empty = scorer.dag_score(&Dag::new(net.n_vars()));
        assert!(truth > empty, "true {truth} vs empty {empty}");
    }

    #[test]
    fn ll_monotone_in_parents_bic_not() {
        let data = data();
        let ll = Scorer::new(&data, ScoreKind::LogLikelihood);
        // Adding any parent never decreases LL.
        let base = ll.family_score(4, &[]);
        let with_p = ll.family_score(4, &[2]);
        let with_pp = ll.family_score(4, &[1, 2]);
        assert!(with_p >= base - 1e-9);
        assert!(with_pp >= with_p - 1e-9);
        // BIC penalizes the irrelevant parent 1 (dyspnoea ⟂ smoker | cancer).
        let bic = Scorer::new(&data, ScoreKind::Bic);
        assert!(bic.family_score(4, &[2]) > bic.family_score(4, &[1, 2]));
    }

    #[test]
    fn cache_hits() {
        let data = data();
        let s = Scorer::new(&data, ScoreKind::Bic);
        let a = s.family_score(0, &[1]);
        let b = s.family_score(0, &[1]);
        assert_eq!(a, b);
        assert_eq!(s.cached_families(), 1);
    }

    #[test]
    fn score_kinds_ordering() {
        let data = data();
        // For the same family, LL >= AIC >= BIC (penalties grow).
        let v = 2;
        let ps = &[0usize, 1][..];
        let ll = Scorer::new(&data, ScoreKind::LogLikelihood).family_score(v, ps);
        let aic = Scorer::new(&data, ScoreKind::Aic).family_score(v, ps);
        let bic = Scorer::new(&data, ScoreKind::Bic).family_score(v, ps);
        assert!(ll >= aic && aic >= bic);
    }
}
