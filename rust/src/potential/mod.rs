//! Potential tables — "a crucial underlying data structure in PGMs"
//! (paper §3, optimization (v)).
//!
//! A [`PotentialTable`] is a non-negative real-valued function over the
//! joint states of an ordered set of discrete variables, stored as a dense
//! row-major array (last variable fastest). Fast-PGM keeps every table
//! *canonical* — variables sorted ascending by `VarId` — which is the
//! reproduction of the paper's potential-table **reorganization**: when all
//! tables share one global variable order, the index map between a table
//! and any sub-table is monotone, so products, marginalizations and
//! divisions become single linear *odometer* scans with incremental index
//! maintenance instead of per-entry divide/modulo decoding. The naive
//! decode path is kept (see [`ops`]) as the ablation baseline for bench E4.

pub mod kernel;
pub mod ops;

use crate::core::{Evidence, VarId};

/// Dense potential over a sorted set of discrete variables.
#[derive(Clone, Debug, PartialEq)]
pub struct PotentialTable {
    /// Scope, strictly increasing.
    vars: Vec<VarId>,
    /// Cardinality of each scope variable.
    cards: Vec<usize>,
    /// Row-major strides (last variable has stride 1).
    strides: Vec<usize>,
    /// `data.len() == cards.iter().product()`.
    data: Vec<f64>,
}

impl PotentialTable {
    /// A table of ones (multiplicative identity) over the given scope.
    /// `vars` must be strictly increasing; `cards[i]` is the cardinality of
    /// `vars[i]`.
    pub fn unit(vars: Vec<VarId>, cards: Vec<usize>) -> Self {
        Self::filled(vars, cards, 1.0)
    }

    /// A table of zeros (additive identity) over the given scope.
    pub fn zeros(vars: Vec<VarId>, cards: Vec<usize>) -> Self {
        Self::filled(vars, cards, 0.0)
    }

    /// A constant table.
    pub fn filled(vars: Vec<VarId>, cards: Vec<usize>, value: f64) -> Self {
        assert_eq!(vars.len(), cards.len());
        assert!(vars.windows(2).all(|w| w[0] < w[1]), "scope must be sorted: {vars:?}");
        assert!(cards.iter().all(|&c| c >= 1));
        let size: usize = cards.iter().product();
        let strides = Self::compute_strides(&cards);
        PotentialTable { vars, cards, strides, data: vec![value; size] }
    }

    /// Build from explicit data laid out row-major over `vars` (sorted).
    pub fn from_data(vars: Vec<VarId>, cards: Vec<usize>, data: Vec<f64>) -> Self {
        let mut t = Self::zeros(vars, cards);
        assert_eq!(t.data.len(), data.len(), "data size mismatch");
        t.data = data;
        t
    }

    /// The empty-scope scalar table.
    pub fn scalar(value: f64) -> Self {
        PotentialTable {
            vars: Vec::new(),
            cards: Vec::new(),
            strides: Vec::new(),
            data: vec![value],
        }
    }

    fn compute_strides(cards: &[usize]) -> Vec<usize> {
        let mut strides = vec![1; cards.len()];
        for i in (0..cards.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * cards[i + 1];
        }
        strides
    }

    pub fn vars(&self) -> &[VarId] {
        &self.vars
    }

    pub fn cards(&self) -> &[usize] {
        &self.cards
    }

    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Does the scope contain `v`?
    pub fn contains_var(&self, v: VarId) -> bool {
        self.vars.binary_search(&v).is_ok()
    }

    /// Position of `v` within the scope.
    pub fn var_position(&self, v: VarId) -> Option<usize> {
        self.vars.binary_search(&v).ok()
    }

    /// Cardinality of scope variable `v`.
    pub fn card_of(&self, v: VarId) -> Option<usize> {
        self.var_position(v).map(|i| self.cards[i])
    }

    /// Flat index of a scope assignment (`digits[i]` is the state of
    /// `vars[i]`).
    #[inline]
    pub fn index_of(&self, digits: &[usize]) -> usize {
        debug_assert_eq!(digits.len(), self.vars.len());
        digits
            .iter()
            .zip(&self.strides)
            .map(|(&d, &s)| d * s)
            .sum()
    }

    /// Decode a flat index into scope digits (naive-path helper).
    pub fn digits_of(&self, mut index: usize, out: &mut [usize]) {
        for (i, &s) in self.strides.iter().enumerate() {
            out[i] = index / s;
            index %= s;
        }
    }

    /// Value at a scope assignment.
    pub fn value_at(&self, digits: &[usize]) -> f64 {
        self.data[self.index_of(digits)]
    }

    pub fn set_at(&mut self, digits: &[usize], value: f64) {
        let i = self.index_of(digits);
        self.data[i] = value;
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Scale so entries sum to 1. Returns the pre-normalization mass
    /// (useful as P(evidence) after absorption). A zero table is left
    /// untouched.
    pub fn normalize(&mut self) -> f64 {
        let s = self.sum();
        if s > 0.0 {
            let inv = 1.0 / s;
            for x in &mut self.data {
                *x *= inv;
            }
        }
        s
    }

    /// Zero out every entry inconsistent with the evidence (standard
    /// junction-tree evidence absorption). Evidence variables outside the
    /// scope are ignored.
    ///
    /// Row-major layout makes the inconsistent entries of one observed
    /// variable a periodic pattern of contiguous runs: for scope position
    /// `p` with stride `s` and cardinality `c`, entries repeat in blocks of
    /// `s * c`, and within each block the run `[state*s, (state+1)*s)` is
    /// the only consistent one. So instead of walking a per-entry odometer
    /// and testing every digit (the old path, kept as
    /// [`PotentialTable::reduce_evidence_scan`]), zero the complement of
    /// that run block by block with plain slice fills — memset-speed, no
    /// digit bookkeeping, and runs of consistent entries are never touched.
    pub fn reduce_evidence(&mut self, ev: &Evidence) {
        for (v, s) in ev.iter() {
            self.reduce_observation(v, s);
        }
    }

    /// Absorb a single observation `v = s` (see
    /// [`PotentialTable::reduce_evidence`]). Taking the pair directly lets
    /// the calibration hot path absorb per-variable deltas without
    /// building a temporary one-entry [`Evidence`] on the heap.
    pub fn reduce_observation(&mut self, v: VarId, s: usize) {
        let p = match self.var_position(v) {
            Some(p) => p,
            None => return,
        };
        let card = self.cards[p];
        if s >= card {
            // Out-of-range state: no entry is consistent (matches the
            // scan path, where `digits[p] != s` holds everywhere).
            self.data.fill(0.0);
            return;
        }
        let stride = self.strides[p];
        let block = stride * card;
        let keep_lo = s * stride;
        let keep_hi = keep_lo + stride;
        for chunk in self.data.chunks_exact_mut(block) {
            chunk[..keep_lo].fill(0.0);
            chunk[keep_hi..].fill(0.0);
        }
    }

    /// Reference implementation of [`PotentialTable::reduce_evidence`]: a
    /// full odometer scan testing every entry against every observation.
    /// Kept as the property-test oracle for the strided fast path and as
    /// an ablation baseline.
    pub fn reduce_evidence_scan(&mut self, ev: &Evidence) {
        // Collect (position, state) pairs inside the scope.
        let obs: Vec<(usize, usize)> = ev
            .iter()
            .filter_map(|(v, s)| self.var_position(v).map(|p| (p, s)))
            .collect();
        if obs.is_empty() {
            return;
        }
        let mut digits = vec![0usize; self.vars.len()];
        for i in 0..self.data.len() {
            // Odometer instead of decode: digits track i.
            if obs.iter().any(|&(p, s)| digits[p] != s) {
                self.data[i] = 0.0;
            }
            Self::advance(&mut digits, &self.cards);
        }
    }

    /// Advance mixed-radix digits by one (odometer). Wraps to all-zero at
    /// the end.
    #[inline]
    pub fn advance(digits: &mut [usize], cards: &[usize]) {
        for i in (0..digits.len()).rev() {
            digits[i] += 1;
            if digits[i] < cards[i] {
                return;
            }
            digits[i] = 0;
        }
    }

    /// Largest entry (diagnostics / MAP-ish queries).
    pub fn max(&self) -> f64 {
        self.data.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Multiply every entry by a scalar.
    pub fn scale(&mut self, k: f64) {
        for x in &mut self.data {
            *x *= k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let t = PotentialTable::unit(vec![0, 2, 5], vec![2, 3, 4]);
        assert_eq!(t.strides(), &[12, 4, 1]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.index_of(&[1, 2, 3]), 12 + 8 + 3);
    }

    #[test]
    fn digits_roundtrip() {
        let t = PotentialTable::unit(vec![1, 3], vec![3, 4]);
        let mut d = [0usize; 2];
        for i in 0..t.len() {
            t.digits_of(i, &mut d);
            assert_eq!(t.index_of(&d), i);
        }
    }

    #[test]
    fn odometer_matches_decode() {
        let t = PotentialTable::unit(vec![0, 1, 2], vec![2, 3, 2]);
        let mut odo = vec![0usize; 3];
        let mut dec = vec![0usize; 3];
        for i in 0..t.len() {
            t.digits_of(i, &mut dec);
            assert_eq!(odo, dec, "at {i}");
            PotentialTable::advance(&mut odo, t.cards());
        }
        assert_eq!(odo, vec![0, 0, 0], "wraps at end");
    }

    #[test]
    #[should_panic]
    fn unsorted_scope_rejected() {
        let _ = PotentialTable::unit(vec![2, 0], vec![2, 2]);
    }

    #[test]
    fn normalize_returns_mass() {
        let mut t =
            PotentialTable::from_data(vec![0], vec![4], vec![1.0, 3.0, 0.0, 4.0]);
        let mass = t.normalize();
        assert!((mass - 8.0).abs() < 1e-12);
        assert!((t.sum() - 1.0).abs() < 1e-12);
        assert!((t.value_at(&[3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn normalize_zero_table_noop() {
        let mut t = PotentialTable::zeros(vec![0], vec![3]);
        assert_eq!(t.normalize(), 0.0);
        assert_eq!(t.sum(), 0.0);
    }

    #[test]
    fn reduce_evidence_zeroes_inconsistent() {
        let mut t = PotentialTable::unit(vec![0, 1], vec![2, 3]);
        let ev = Evidence::new().with(1, 2).with(9, 0); // 9 not in scope
        t.reduce_evidence(&ev);
        for a in 0..2 {
            for b in 0..3 {
                let expect = if b == 2 { 1.0 } else { 0.0 };
                assert_eq!(t.value_at(&[a, b]), expect);
            }
        }
    }

    #[test]
    fn reduce_evidence_strided_matches_scan() {
        // Multi-variable evidence, middle/first/last scope positions, and
        // an out-of-scope variable: strided and scan paths must agree
        // bit-for-bit.
        let mut a = PotentialTable::unit(vec![0, 2, 5, 6], vec![2, 3, 2, 4]);
        for (i, x) in a.data_mut().iter_mut().enumerate() {
            *x = i as f64 + 1.0;
        }
        let mut b = a.clone();
        let ev = Evidence::new().with(0, 1).with(5, 0).with(6, 3).with(9, 1);
        a.reduce_evidence(&ev);
        b.reduce_evidence_scan(&ev);
        assert_eq!(a, b);
    }

    #[test]
    fn reduce_evidence_out_of_range_state_zeroes_all() {
        let mut a = PotentialTable::unit(vec![0, 1], vec![2, 3]);
        let mut b = a.clone();
        let ev = Evidence::new().with(1, 7);
        a.reduce_evidence(&ev);
        b.reduce_evidence_scan(&ev);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_table() {
        let t = PotentialTable::scalar(3.5);
        assert_eq!(t.len(), 1);
        assert_eq!(t.sum(), 3.5);
        assert!(t.vars().is_empty());
    }
}
