//! Compiled message kernels — preplanned, fused, arena-backed table
//! operations for the junction-tree hot loop.
//!
//! The classic calibration path executes each Hugin message as three
//! generic table operations (`marginalize_keep` → `divide_subset` →
//! `multiply_subset`), re-deriving the union scope and the mapped stride
//! vectors and allocating fresh tables on every message of every
//! calibration. None of that work depends on the evidence: the scope
//! algebra is a function of the tree alone. This module moves it to
//! compile time (the PGMax "flatten messages into preplanned arrays with a
//! compiled schedule" lever, and OpenGM's model-vs-bound-dispatch split):
//!
//! * [`ScanPlan`] — the precomputed mapping of one clique-table scan onto
//!   a separator scope: mapped strides, the outer/inner scan split, run
//!   count. Built once per directed edge, reused by every calibration.
//! * [`MsgPlan`] / [`KernelPlans`] — per-edge plan pairs (child↔sep and
//!   parent↔sep share one separator, so one plan pair serves both the
//!   collect and the distribute direction) plus the topological
//!   [`MessageSchedule`].
//! * [`TableArena`] — a bump region sized once from the tree's worst-case
//!   per-edge working set. On the non-intra scan paths, steady-state
//!   fused calibration allocates nothing on the heap per message;
//!   [`TableArena::allocations`] counts backing (re)allocations so tests
//!   and benches can assert exactly that. (The `*_intra` variants trade
//!   tiny span-local digit buffers and scoped worker threads for
//!   within-clique parallelism.)
//! * Fused kernels — [`marginalize_into`] computes the new sepset message
//!   in one scan of the source clique; [`ratio_and_store`] forms the Hugin
//!   ratio against the retained old message *and* stores the new message
//!   in the same pass; [`absorb_into`] multiplies the ratio into the
//!   destination clique in one scan. No intermediate `PotentialTable` is
//!   ever materialized. `*_intra` variants split the scan's run range over
//!   worker threads for the big cliques that dominate wall time (the
//!   within-clique dimension of the paper's hybrid parallelism).
//!
//! The classic path ([`KernelMode::Classic`]) is retained as the
//! correctness oracle and the ablation baseline of `bench_kernels`.

use crate::core::VarId;
use crate::parallel::{parallel_for_dynamic, SyncPtr};
use std::sync::OnceLock;
use std::time::Instant;

/// Which message-passing implementation a calibration engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Preplanned fused kernels over the [`TableArena`] (default).
    #[default]
    Fused,
    /// The original three-op path over generic table operations — the
    /// correctness oracle and ablation baseline.
    Classic,
    /// Fused kernels over *stacked* clique tables: one blocked pass per
    /// message edge calibrates a whole flush group of evidence lanes at
    /// once (single-evidence calls fall back to the fused scalar path).
    Batched,
}

impl KernelMode {
    /// Every mode, in CLI-spelling order.
    pub const ALL: [KernelMode; 3] =
        [KernelMode::Fused, KernelMode::Classic, KernelMode::Batched];

    /// The accepted CLI spellings, `|`-joined — the one string usage text
    /// and parse errors quote, so a new mode cannot drift out of sync.
    pub const SPELLINGS: &'static str = "fused|classic|batched";

    /// The canonical spelling: CLI flag value, metrics label, bench JSON
    /// field, wire label — one string for all of them.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelMode::Fused => "fused",
            KernelMode::Classic => "classic",
            KernelMode::Batched => "batched",
        }
    }

    /// Parse a CLI spelling (the `Option` twin of the [`std::str::FromStr`]
    /// impl).
    pub fn parse(s: &str) -> Option<KernelMode> {
        KernelMode::ALL.into_iter().find(|m| m.as_str() == s)
    }

    /// Stable label for metrics and bench JSON (alias of
    /// [`KernelMode::as_str`]).
    pub fn label(self) -> &'static str {
        self.as_str()
    }
}

impl std::str::FromStr for KernelMode {
    type Err = String;

    fn from_str(s: &str) -> Result<KernelMode, String> {
        KernelMode::parse(s)
            .ok_or_else(|| format!("unknown kernel mode {s:?} ({})", KernelMode::SPELLINGS))
    }
}

/// SIMD register width in `f64` lanes that batched kernels pad the lane
/// dimension to (8 × f64 = one 512-bit register, two 256-bit AVX2
/// registers, four 128-bit NEON registers — every per-entry lane loop is a
/// whole number of vector operations with no scalar tail).
pub const SIMD_WIDTH: usize = 8;

/// Round a batch size up to a whole number of SIMD registers — the lane
/// stride of the stacked (SoA) clique layout. Zero stays zero.
pub fn padded_lanes(batch: usize) -> usize {
    batch.div_ceil(SIMD_WIDTH) * SIMD_WIDTH
}

/// The legacy fixed intra-clique parallelism threshold, retained as the
/// reference point of the per-edge microcalibrated thresholds: a machine
/// scanning ~1 entry/ns reproduces it. See [`edge_intra_min_len`].
pub const INTRA_MIN_LEN: usize = 1 << 12;

/// Clamp range of the microcalibrated per-edge threshold — the derivation
/// below never strays more than 8× either side of the legacy constant,
/// whatever the timer says.
const INTRA_LEN_CLAMP: (usize, usize) = (INTRA_MIN_LEN >> 3, INTRA_MIN_LEN << 3);

/// Odometer bookkeeping per run, expressed in table-entry scan
/// equivalents: short inner runs pay this much extra per entry, which
/// lowers the length at which span-splitting pays off.
const RUN_OVERHEAD_ENTRIES: f64 = 4.0;

/// One-time microcalibration: sequential scan cost in ns per table entry,
/// measured once per process over a cache-resident buffer (best of a few
/// reps, so scheduler noise only ever *raises* the sample we discard).
fn scan_ns_per_entry() -> f64 {
    static CELL: OnceLock<f64> = OnceLock::new();
    *CELL.get_or_init(|| {
        const N: usize = 1 << 16;
        let buf: Vec<f64> = (0..N).map(|i| (i % 97) as f64 * 0.125 + 0.5).collect();
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            let mut acc = 0.0f64;
            for &x in &buf {
                acc += x;
            }
            std::hint::black_box(acc);
            best = best.min(t0.elapsed().as_nanos() as f64 / N as f64);
        }
        best.max(0.01)
    })
}

/// Test-determinism override of every per-edge threshold:
/// `FASTPGM_INTRA_MIN_LEN=<n>` pins the microcalibrated value.
fn intra_len_override() -> Option<usize> {
    static CELL: OnceLock<Option<usize>> = OnceLock::new();
    *CELL.get_or_init(|| {
        std::env::var("FASTPGM_INTRA_MIN_LEN").ok().and_then(|s| s.parse().ok())
    })
}

/// Per-edge intra-clique parallelism threshold, derived from measured scan
/// cost at plan-compile time: span-splitting a scan is worth a fixed
/// dispatch budget (≈ [`INTRA_MIN_LEN`] ns), so the eligible table length
/// is that budget divided by the edge's effective per-entry cost — which
/// rises for short inner runs, where odometer bookkeeping amortizes badly.
/// `FASTPGM_INTRA_MIN_LEN` overrides the measurement for deterministic
/// tests.
pub fn edge_intra_min_len(inner_run_len: usize) -> usize {
    if let Some(v) = intra_len_override() {
        return v;
    }
    let per_entry = scan_ns_per_entry()
        * (1.0 + RUN_OVERHEAD_ENTRIES / inner_run_len.max(1) as f64);
    ((INTRA_MIN_LEN as f64 / per_entry) as usize).clamp(INTRA_LEN_CLAMP.0, INTRA_LEN_CLAMP.1)
}

/// Precomputed mapping of one clique-table scan onto a separator scope.
///
/// The scan enumerates the clique table in flat (row-major) order as
/// `n_runs` contiguous runs of `inner` entries (the last axis hoisted out
/// of the odometer, as in the classic optimized path); `sep_map[pos]` is
/// the separator stride contributed by clique scope position `pos` (0 when
/// the variable is summed out / broadcast), `sep_step` is the per-entry
/// separator step inside a run.
#[derive(Clone, Debug)]
pub struct ScanPlan {
    /// Shape of the scanned clique table.
    cards: Vec<usize>,
    /// `cards` product — the scanned table's length.
    len: usize,
    /// Separator stride of each clique scope position.
    sep_map: Vec<usize>,
    /// Row-major strides over the *outer* axes (`cards[..k-1]`), for
    /// decoding a run index when a scan is split across workers.
    outer_strides: Vec<usize>,
    /// Run length: cardinality of the last axis (1 for empty scopes).
    inner: usize,
    /// Separator step of the last axis (0 = a run maps to one sep cell).
    sep_step: usize,
    /// Number of runs (`len / inner`).
    n_runs: usize,
    /// Separator size this plan maps onto.
    sep_len: usize,
}

impl ScanPlan {
    /// Plan the scan of a table over `(vars, cards)` mapped onto the
    /// separator scope `(sep_vars, sep_cards)`. Both scopes must be sorted
    /// and `sep_vars ⊆ vars`.
    pub fn new(
        vars: &[VarId],
        cards: &[usize],
        sep_vars: &[VarId],
        sep_cards: &[usize],
    ) -> ScanPlan {
        debug_assert_eq!(vars.len(), cards.len());
        debug_assert_eq!(sep_vars.len(), sep_cards.len());
        debug_assert!(sep_vars.iter().all(|v| vars.contains(v)), "sep ⊄ scope");
        let sep_len: usize = sep_cards.iter().product::<usize>().max(1);
        // Row-major strides of the separator scope.
        let mut sep_strides = vec![1usize; sep_vars.len()];
        for i in (0..sep_vars.len().saturating_sub(1)).rev() {
            sep_strides[i] = sep_strides[i + 1] * sep_cards[i + 1];
        }
        let sep_map: Vec<usize> = vars
            .iter()
            .map(|v| {
                sep_vars
                    .binary_search(v)
                    .map_or(0, |p| sep_strides[p])
            })
            .collect();
        let len: usize = cards.iter().product::<usize>().max(1);
        let (inner, sep_step) = match cards.last() {
            Some(&c) => (c, sep_map[cards.len() - 1]),
            None => (1, 0),
        };
        let outer = cards.len().saturating_sub(1);
        let mut outer_strides = vec![1usize; outer];
        for i in (0..outer.saturating_sub(1)).rev() {
            outer_strides[i] = outer_strides[i + 1] * cards[i + 1];
        }
        ScanPlan {
            cards: cards.to_vec(),
            len,
            sep_map,
            outer_strides,
            inner,
            sep_step,
            n_runs: len / inner,
            sep_len,
        }
    }

    /// Length of the scanned table.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Never empty — an empty scope is a one-entry scalar table.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Scope arity of the scanned table (digit-scratch sizing).
    pub fn arity(&self) -> usize {
        self.cards.len()
    }

    /// Separator size this plan maps onto.
    pub fn sep_len(&self) -> usize {
        self.sep_len
    }

    /// Drive the full scan: `run(i, is)` is called once per run, where
    /// `i` is the flat start of the run in the scanned table and `is` the
    /// mapped separator index at the start of the run. `digits` is caller-
    /// provided odometer scratch of length ≥ `arity()` (no allocation on
    /// the hot path).
    #[inline]
    pub fn for_runs(&self, digits: &mut [usize], mut run: impl FnMut(usize, usize)) {
        let outer = self.cards.len().saturating_sub(1);
        let outer_cards = &self.cards[..outer];
        let digits = &mut digits[..outer];
        digits.fill(0);
        let mut i = 0usize;
        let mut is = 0usize;
        for _ in 0..self.n_runs {
            run(i, is);
            i += self.inner;
            for pos in (0..outer).rev() {
                digits[pos] += 1;
                if digits[pos] < outer_cards[pos] {
                    is += self.sep_map[pos];
                    break;
                }
                digits[pos] = 0;
                is -= self.sep_map[pos] * (outer_cards[pos] - 1);
            }
        }
    }

    /// Drive runs `lo..hi` only (a span of a split scan): decodes the
    /// starting odometer state from the run index, then proceeds as
    /// [`ScanPlan::for_runs`]. Allocates its (tiny) digit buffer — used
    /// only on the intra-parallel path, where a span is a worker-sized
    /// unit of work.
    pub fn for_runs_span(&self, lo: usize, hi: usize, mut run: impl FnMut(usize, usize)) {
        let outer = self.cards.len().saturating_sub(1);
        let outer_cards = &self.cards[..outer];
        let mut digits = vec![0usize; outer];
        let mut rem = lo;
        for pos in 0..outer {
            digits[pos] = rem / self.outer_strides[pos];
            rem %= self.outer_strides[pos];
        }
        let mut is: usize =
            digits.iter().zip(&self.sep_map).map(|(&d, &s)| d * s).sum();
        let mut i = lo * self.inner;
        for _ in lo..hi {
            run(i, is);
            i += self.inner;
            for pos in (0..outer).rev() {
                digits[pos] += 1;
                if digits[pos] < outer_cards[pos] {
                    is += self.sep_map[pos];
                    break;
                }
                digits[pos] = 0;
                is -= self.sep_map[pos] * (outer_cards[pos] - 1);
            }
        }
    }
}

/// Marginalize `src` (scanned per `plan`) into the separator buffer `out`.
/// Identical accumulation order to the classic odometer
/// `marginalize_keep`, so results are bit-equal to it.
pub fn marginalize_into(plan: &ScanPlan, src: &[f64], out: &mut [f64], digits: &mut [usize]) {
    debug_assert_eq!(src.len(), plan.len);
    debug_assert_eq!(out.len(), plan.sep_len);
    out.fill(0.0);
    let inner = plan.inner;
    let step = plan.sep_step;
    plan.for_runs(digits, |i, is| {
        if step == 0 {
            // Run collapses into one separator cell: tight reduction.
            let mut acc = 0.0;
            for &x in &src[i..i + inner] {
                acc += x;
            }
            out[is] += acc;
        } else {
            let mut is = is;
            for &x in &src[i..i + inner] {
                out[is] += x;
                is += step;
            }
        }
    });
}

/// Intra-parallel [`marginalize_into`]: the run range is split into
/// `spans` worker units, each reducing into its own span-private region of
/// `scratch` (no atomics on the hot path), then folded into `out`.
pub fn marginalize_into_intra(
    plan: &ScanPlan,
    src: &[f64],
    out: &mut [f64],
    scratch: &mut [f64],
    spans: usize,
    threads: usize,
) {
    let sep_len = plan.sep_len;
    debug_assert!(scratch.len() >= spans * sep_len);
    let scratch = &mut scratch[..spans * sep_len];
    scratch.fill(0.0);
    let span_runs = plan.n_runs.div_ceil(spans);
    let n_runs = plan.n_runs;
    let inner = plan.inner;
    let step = plan.sep_step;
    let ptr = SyncPtr(scratch.as_mut_ptr());
    let ptr_ref = &ptr; // capture the Sync wrapper, not its field
    parallel_for_dynamic(spans, threads, 1, move |w| {
        let lo = w * span_runs;
        let hi = ((w + 1) * span_runs).min(n_runs);
        if lo >= hi {
            return;
        }
        // SAFETY: span `w` writes only `scratch[w*sep_len .. (w+1)*sep_len]`
        // — regions are disjoint by construction.
        let acc =
            unsafe { std::slice::from_raw_parts_mut(ptr_ref.0.add(w * sep_len), sep_len) };
        plan.for_runs_span(lo, hi, |i, is| {
            if step == 0 {
                let mut sum = 0.0;
                for &x in &src[i..i + inner] {
                    sum += x;
                }
                acc[is] += sum;
            } else {
                let mut is = is;
                for &x in &src[i..i + inner] {
                    acc[is] += x;
                    is += step;
                }
            }
        });
    });
    out.fill(0.0);
    for part in scratch.chunks_exact(sep_len) {
        for (o, &x) in out.iter_mut().zip(part) {
            *o += x;
        }
    }
}

/// Form the Hugin ratio `new / old` (junction-tree convention `x/0 = 0`)
/// into `ratio` and retain `new` as the stored sepset message — one pass
/// over the (small) separator, no intermediate message table.
pub fn ratio_and_store(new_msg: &[f64], retained: &mut [f64], ratio: &mut [f64]) {
    debug_assert_eq!(new_msg.len(), retained.len());
    debug_assert_eq!(new_msg.len(), ratio.len());
    for ((r, old), &new) in ratio.iter_mut().zip(retained.iter_mut()).zip(new_msg) {
        *r = if *old == 0.0 { 0.0 } else { new / *old };
        *old = new;
    }
}

/// Multiply the separator-scoped `ratio` into `dst` (scanned per `plan`)
/// — the absorb half of a Hugin message, identical scan order to the
/// classic odometer `multiply_subset`.
pub fn absorb_into(plan: &ScanPlan, ratio: &[f64], dst: &mut [f64], digits: &mut [usize]) {
    debug_assert_eq!(dst.len(), plan.len);
    debug_assert_eq!(ratio.len(), plan.sep_len);
    let inner = plan.inner;
    let step = plan.sep_step;
    plan.for_runs(digits, |i, is| {
        if step == 0 {
            let v = ratio[is];
            for x in &mut dst[i..i + inner] {
                *x *= v;
            }
        } else {
            let mut is = is;
            for x in &mut dst[i..i + inner] {
                *x *= ratio[is];
                is += step;
            }
        }
    });
}

/// Intra-parallel [`absorb_into`]: runs are split across workers; every
/// run is written by exactly one span, so writes are disjoint.
pub fn absorb_into_intra(
    plan: &ScanPlan,
    ratio: &[f64],
    dst: &mut [f64],
    spans: usize,
    threads: usize,
) {
    debug_assert_eq!(dst.len(), plan.len);
    let span_runs = plan.n_runs.div_ceil(spans);
    let n_runs = plan.n_runs;
    let inner = plan.inner;
    let step = plan.sep_step;
    let ptr = SyncPtr(dst.as_mut_ptr());
    let ptr_ref = &ptr; // capture the Sync wrapper, not its field
    parallel_for_dynamic(spans, threads, 1, move |w| {
        let lo = w * span_runs;
        let hi = ((w + 1) * span_runs).min(n_runs);
        if lo >= hi {
            return;
        }
        plan.for_runs_span(lo, hi, |i, is| {
            // SAFETY: runs are disjoint `inner`-sized slices and each run
            // belongs to exactly one span.
            let run = unsafe { std::slice::from_raw_parts_mut(ptr_ref.0.add(i), inner) };
            if step == 0 {
                let v = ratio[is];
                for x in run {
                    *x *= v;
                }
            } else {
                let mut is = is;
                for x in run {
                    *x *= ratio[is];
                    is += step;
                }
            }
        });
    });
}

// ---------------------------------------------------------------------------
// Batched (stacked-lane) kernel variants.
//
// The stacked layout is index-major SoA: a clique table of `len` entries
// carrying `lanes` evidence lanes is a buffer of `len * lanes` f64s with
// entry `t` of lane `b` at `t * lanes + b`. One ScanPlan drive then serves
// every lane at once, and each scalar operation of the fused kernels
// becomes a contiguous `lanes`-length loop — `lanes` is padded to
// [`SIMD_WIDTH`], so those loops are whole vector registers and the
// compiler autovectorizes them with no scalar tail. Per lane, the
// arithmetic sequence is identical to the scalar fused kernels, so results
// are bit-equal lane by lane.
// ---------------------------------------------------------------------------

/// Batched [`marginalize_into`]: `src` and `out` are stacked buffers of
/// `plan.len() * lanes` and `plan.sep_len() * lanes` entries.
pub fn marginalize_batch_into(
    plan: &ScanPlan,
    src: &[f64],
    out: &mut [f64],
    lanes: usize,
    digits: &mut [usize],
) {
    debug_assert_eq!(src.len(), plan.len * lanes);
    debug_assert_eq!(out.len(), plan.sep_len * lanes);
    out.fill(0.0);
    let inner = plan.inner;
    let step = plan.sep_step;
    plan.for_runs(digits, |i, is| {
        if step == 0 {
            // Run collapses into one separator cell. Mirror the scalar
            // kernel's order *per lane* — a run-local accumulator summed
            // over the run, then added into the cell once — so every lane
            // is bit-equal to `marginalize_into`. Lanes are processed in
            // SIMD_WIDTH-sized register blocks with a fixed-size stack
            // accumulator (no heap, fully unrollable).
            let cell = &mut out[is * lanes..(is + 1) * lanes];
            let mut l = 0;
            while l < lanes {
                let w = SIMD_WIDTH.min(lanes - l);
                let mut acc = [0.0f64; SIMD_WIDTH];
                for r in 0..inner {
                    let row = &src[(i + r) * lanes + l..][..w];
                    for (a, &x) in acc[..w].iter_mut().zip(row) {
                        *a += x;
                    }
                }
                for (o, &a) in cell[l..l + w].iter_mut().zip(&acc[..w]) {
                    *o += a;
                }
                l += w;
            }
        } else {
            // Strided case: the scalar kernel adds entry by entry, so the
            // direct lane-vector accumulation is already bit-equal.
            let mut is = is;
            for r in 0..inner {
                let row = &src[(i + r) * lanes..(i + r + 1) * lanes];
                let acc = &mut out[is * lanes..(is + 1) * lanes];
                for (a, &x) in acc.iter_mut().zip(row) {
                    *a += x;
                }
                is += step;
            }
        }
    });
}

/// Batched [`ratio_and_store`]: elementwise over stacked separator
/// buffers, so no plan is needed — the scalar convention (`x/0 = 0`)
/// applies per lane.
pub fn ratio_and_store_batch(new_msg: &[f64], retained: &mut [f64], ratio: &mut [f64]) {
    // Identical elementwise kernel; the stacked layout changes nothing.
    ratio_and_store(new_msg, retained, ratio);
}

/// Batched [`absorb_into`]: multiply the stacked separator-scoped `ratio`
/// into the stacked destination clique `dst`, lane by lane.
pub fn absorb_batch_into(
    plan: &ScanPlan,
    ratio: &[f64],
    dst: &mut [f64],
    lanes: usize,
    digits: &mut [usize],
) {
    debug_assert_eq!(dst.len(), plan.len * lanes);
    debug_assert_eq!(ratio.len(), plan.sep_len * lanes);
    let inner = plan.inner;
    let step = plan.sep_step;
    plan.for_runs(digits, |i, is| {
        let mut is = is;
        for r in 0..inner {
            let row = &mut dst[(i + r) * lanes..(i + r + 1) * lanes];
            let k = &ratio[is * lanes..(is + 1) * lanes];
            for (x, &v) in row.iter_mut().zip(k) {
                *x *= v;
            }
            if step != 0 {
                is += step;
            }
        }
    });
}

/// The plan pair of one tree edge: child↔separator and parent↔separator.
/// Collect (child → parent) marginalizes with `child` and absorbs with
/// `parent`; distribute reverses the roles. One separator serves both.
#[derive(Clone, Debug)]
pub struct MsgPlan {
    /// Separator table length.
    pub sep_len: usize,
    /// Scan of the child clique mapped onto the separator.
    pub child: ScanPlan,
    /// Scan of the parent clique mapped onto the separator.
    pub parent: ScanPlan,
    /// This edge's intra-clique parallelism threshold (table length at
    /// which span-splitting pays off), microcalibrated at plan-compile
    /// time — see [`edge_intra_min_len`]. Stored on the plan so the arena
    /// layout and the message dispatch always agree on eligibility.
    pub intra_min_len: usize,
}

/// Topological message schedule: for each tree depth, the cliques that
/// exchange messages with children at that depth. Collect walks the levels
/// deepest-first, distribute shallowest-first; leaf-only levels are
/// pre-filtered out of the dispatch entirely.
#[derive(Clone, Debug)]
pub struct MessageSchedule {
    /// `active_parents[d]` = cliques at depth `d` with at least one child.
    pub active_parents: Vec<Vec<usize>>,
}

/// All compile-time kernel state of one junction tree: per-edge plans and
/// the message schedule. Built once by `JunctionTree::build`, shared by
/// every engine and every calibration.
#[derive(Clone, Debug)]
pub struct KernelPlans {
    /// Indexed by clique; `None` for the root (it has no parent edge).
    msgs: Vec<Option<MsgPlan>>,
    pub schedule: MessageSchedule,
}

impl KernelPlans {
    /// Build plans for a rooted clique tree. `cliques[i]`/`separators[i]`
    /// are sorted scopes, `cards[v]` global cardinalities, `levels` the
    /// depth partition, `children` the per-clique child lists.
    pub fn build(
        cliques: &[Vec<VarId>],
        separators: &[Vec<VarId>],
        parent: &[usize],
        children: &[Vec<usize>],
        levels: &[Vec<usize>],
        root: usize,
        cards: &[usize],
    ) -> KernelPlans {
        let scope_cards =
            |scope: &[VarId]| -> Vec<usize> { scope.iter().map(|&v| cards[v]).collect() };
        let msgs: Vec<Option<MsgPlan>> = (0..cliques.len())
            .map(|c| {
                if c == root {
                    return None;
                }
                let p = parent[c];
                let sep = &separators[c];
                let sep_cards = scope_cards(sep);
                let child =
                    ScanPlan::new(&cliques[c], &scope_cards(&cliques[c]), sep, &sep_cards);
                let par =
                    ScanPlan::new(&cliques[p], &scope_cards(&cliques[p]), sep, &sep_cards);
                // Threshold from the dominant (larger) scan of the edge —
                // the one whose cost decides whether splitting pays.
                let big_inner =
                    if child.len() >= par.len() { child.inner } else { par.inner };
                let intra_min_len = edge_intra_min_len(big_inner);
                Some(MsgPlan {
                    sep_len: child.sep_len(),
                    child,
                    parent: par,
                    intra_min_len,
                })
            })
            .collect();
        let active_parents: Vec<Vec<usize>> = levels
            .iter()
            .map(|level| {
                level
                    .iter()
                    .copied()
                    .filter(|&c| !children[c].is_empty())
                    .collect()
            })
            .collect();
        KernelPlans { msgs, schedule: MessageSchedule { active_parents } }
    }

    /// The plan pair of the edge between clique `c` and its parent.
    /// Panics for the root, which has no such edge.
    pub fn msg(&self, c: usize) -> &MsgPlan {
        self.msgs[c].as_ref().expect("root clique has no message plan")
    }

    /// Number of cliques the plans were built for.
    pub fn n_cliques(&self) -> usize {
        self.msgs.len()
    }
}

/// Arena offsets of one edge's working set: the new-message buffer, the
/// ratio buffer, and (for intra-eligible edges) the span-private
/// marginalization scratch.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeSlots {
    pub msg: usize,
    pub ratio: usize,
    pub scratch: usize,
    /// `0` when the edge has no intra scratch region.
    pub scratch_len: usize,
}

/// Per-engine arena layout: one [`EdgeSlots`] per clique (the root's slot
/// is unused) and the total arena length. Regions of distinct edges are
/// disjoint, which is what makes the level-parallel fused path race-free.
#[derive(Clone, Debug, Default)]
pub struct ArenaLayout {
    pub slots: Vec<EdgeSlots>,
    pub total: usize,
}

impl ArenaLayout {
    /// Lay out the arena for `plans`. `intra_spans > 0` reserves
    /// span-private marginalization scratch for edges whose clique tables
    /// reach the edge's microcalibrated [`MsgPlan::intra_min_len`]
    /// threshold (0 = sequential engine, no scratch).
    pub fn build(plans: &KernelPlans, intra_spans: usize) -> ArenaLayout {
        let mut slots = vec![EdgeSlots::default(); plans.n_cliques()];
        let mut off = 0usize;
        for (c, plan) in plans.msgs.iter().enumerate() {
            let Some(plan) = plan else { continue };
            let slot = &mut slots[c];
            slot.msg = off;
            off += plan.sep_len;
            slot.ratio = off;
            off += plan.sep_len;
            let intra_eligible = intra_spans > 0
                && plan.child.len().max(plan.parent.len()) >= plan.intra_min_len;
            if intra_eligible {
                slot.scratch = off;
                slot.scratch_len = intra_spans * plan.sep_len;
                off += slot.scratch_len;
            }
        }
        ArenaLayout { slots, total: off }
    }
}

/// Batch-strided arena layout for one stacked calibration pass: every
/// buffer of the scalar fused path — clique tables, retained sepset
/// messages, per-edge new-message and ratio scratch — widened by `lanes`
/// and laid out in ascending, disjoint regions of one [`TableArena`].
/// Region order (cliques, then sepsets, then per-edge msg+ratio) is what
/// lets the three kernel steps borrow their operand pairs/triples via
/// [`TableArena::two_regions_mut`] / [`TableArena::three_regions_mut`].
#[derive(Clone, Debug, Default)]
pub struct BatchLayout {
    /// Stacked clique-table offset, per clique.
    pub clique: Vec<usize>,
    /// Stacked retained-sepset offset, per non-root clique (root entry
    /// unused).
    pub sep: Vec<usize>,
    /// Per-edge msg/ratio offsets (scratch fields unused — the batched
    /// pass is lane-parallel, not span-parallel).
    pub slots: Vec<EdgeSlots>,
    /// Lane stride the layout was built for.
    pub lanes: usize,
    /// Total arena length in `f64` entries.
    pub total: usize,
}

impl BatchLayout {
    /// Lay out the stacked working set: `clique_lens[c]` is clique `c`'s
    /// table length (the root has no [`MsgPlan`], so lengths cannot come
    /// from `plans` alone), `lanes` the — typically [`padded_lanes`]-padded
    /// — lane stride.
    pub fn build(plans: &KernelPlans, clique_lens: &[usize], lanes: usize) -> BatchLayout {
        debug_assert_eq!(clique_lens.len(), plans.n_cliques());
        let mut off = 0usize;
        let clique: Vec<usize> = clique_lens
            .iter()
            .map(|&len| {
                let o = off;
                off += len * lanes;
                o
            })
            .collect();
        let mut sep = vec![0usize; plans.n_cliques()];
        for (c, plan) in plans.msgs.iter().enumerate() {
            let Some(plan) = plan else { continue };
            sep[c] = off;
            off += plan.sep_len * lanes;
        }
        let mut slots = vec![EdgeSlots::default(); plans.n_cliques()];
        for (c, plan) in plans.msgs.iter().enumerate() {
            let Some(plan) = plan else { continue };
            slots[c].msg = off;
            off += plan.sep_len * lanes;
            slots[c].ratio = off;
            off += plan.sep_len * lanes;
        }
        BatchLayout { clique, sep, slots, lanes, total: off }
    }
}

/// A bump region for message-kernel working buffers, sized once from an
/// [`ArenaLayout`]. Offsets come from the layout; the arena itself only
/// tracks the backing storage and counts (re)allocations so the
/// zero-allocation steady state is assertable.
#[derive(Debug, Default)]
pub struct TableArena {
    buf: Vec<f64>,
    allocations: u64,
}

impl TableArena {
    pub fn new() -> TableArena {
        TableArena::default()
    }

    /// Grow the backing buffer to at least `len` entries. A no-op when the
    /// arena is already large enough — the steady-state path.
    pub fn ensure(&mut self, len: usize) {
        if self.buf.len() < len {
            self.buf = vec![0.0; len];
            self.allocations += 1;
        }
    }

    /// Number of backing (re)allocations since creation. Constant across
    /// repeated calibrations = zero per-message heap allocations.
    pub fn allocations(&self) -> u64 {
        self.allocations
    }

    /// Current capacity in `f64` entries.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn region(&self, off: usize, len: usize) -> &[f64] {
        &self.buf[off..off + len]
    }

    pub fn region_mut(&mut self, off: usize, len: usize) -> &mut [f64] {
        &mut self.buf[off..off + len]
    }

    /// Two disjoint regions at once; the first must end at or before the
    /// second's start (the layout allocates them in ascending order).
    pub fn two_regions_mut(
        &mut self,
        a: (usize, usize),
        b: (usize, usize),
    ) -> (&mut [f64], &mut [f64]) {
        debug_assert!(a.0 + a.1 <= b.0, "arena regions overlap");
        let (lo, hi) = self.buf.split_at_mut(b.0);
        (&mut lo[a.0..a.0 + a.1], &mut hi[..b.1])
    }

    /// Three disjoint regions at once, in ascending offset order — the
    /// batched ratio step borrows retained sepset, new message, and ratio
    /// together.
    pub fn three_regions_mut(
        &mut self,
        a: (usize, usize),
        b: (usize, usize),
        c: (usize, usize),
    ) -> (&mut [f64], &mut [f64], &mut [f64]) {
        debug_assert!(a.0 + a.1 <= b.0, "arena regions overlap");
        debug_assert!(b.0 + b.1 <= c.0, "arena regions overlap");
        let (lo, hi) = self.buf.split_at_mut(c.0);
        let (lo, mid) = lo.split_at_mut(b.0);
        (&mut lo[a.0..a.0 + a.1], &mut mid[..b.1], &mut hi[..c.1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potential::ops::IndexMode;
    use crate::potential::PotentialTable;

    fn table(vars: Vec<VarId>, cards: Vec<usize>, seed: u64) -> PotentialTable {
        let mut t = PotentialTable::zeros(vars, cards);
        let mut s = seed;
        for x in t.data_mut() {
            *x = (crate::rng::splitmix64(&mut s) % 1000) as f64 / 100.0 + 0.01;
        }
        t
    }

    fn plan_for(t: &PotentialTable, sep: &PotentialTable) -> ScanPlan {
        ScanPlan::new(t.vars(), t.cards(), sep.vars(), sep.cards())
    }

    #[test]
    fn marginalize_into_matches_marginalize_keep() {
        let t = table(vec![0, 2, 5, 6], vec![2, 3, 2, 4], 1);
        for keep in [vec![], vec![0], vec![2, 6], vec![0, 2, 5, 6], vec![6]] {
            let expect = t.marginalize_keep(&keep, IndexMode::Odometer);
            let plan = plan_for(&t, &expect);
            let mut out = vec![0.0; expect.len()];
            let mut digits = vec![0usize; plan.arity()];
            marginalize_into(&plan, t.data(), &mut out, &mut digits);
            assert_eq!(out.as_slice(), expect.data(), "keep {keep:?}");
        }
    }

    #[test]
    fn marginalize_intra_matches_sequential() {
        let t = table(vec![0, 1, 2, 3], vec![4, 4, 4, 4], 2);
        let sep = t.marginalize_keep(&[1, 3], IndexMode::Odometer);
        let plan = plan_for(&t, &sep);
        let mut seq = vec![0.0; sep.len()];
        let mut digits = vec![0usize; plan.arity()];
        marginalize_into(&plan, t.data(), &mut seq, &mut digits);
        for spans in [1, 3, 8] {
            let mut par = vec![0.0; sep.len()];
            let mut scratch = vec![0.0; spans * sep.len()];
            marginalize_into_intra(&plan, t.data(), &mut par, &mut scratch, spans, 4);
            for (a, b) in par.iter().zip(&seq) {
                assert!((a - b).abs() < 1e-12, "spans {spans}");
            }
        }
    }

    #[test]
    fn absorb_matches_multiply_subset() {
        let base = table(vec![0, 1, 3], vec![2, 3, 2], 3);
        let sub = table(vec![1, 3], vec![3, 2], 4);
        let mut expect = base.clone();
        expect.multiply_subset(&sub, IndexMode::Odometer);
        let plan = plan_for(&base, &sub);
        let mut got = base.clone();
        let mut digits = vec![0usize; plan.arity()];
        absorb_into(&plan, sub.data(), got.data_mut(), &mut digits);
        assert_eq!(got.data(), expect.data());
        // Intra-parallel split agrees too.
        let mut got2 = base.clone();
        absorb_into_intra(&plan, sub.data(), got2.data_mut(), 5, 4);
        assert_eq!(got2.data(), expect.data());
    }

    #[test]
    fn ratio_and_store_matches_divide_convention() {
        let new_msg = [2.0, 0.0, 6.0, 0.0];
        let mut retained = [4.0, 5.0, 0.0, 0.0];
        let mut ratio = [0.0; 4];
        ratio_and_store(&new_msg, &mut retained, &mut ratio);
        // x/0 = 0 convention (including 0/0), matching divide_subset.
        assert_eq!(ratio, [0.5, 0.0, 0.0, 0.0]);
        assert_eq!(retained, new_msg, "new message must be retained");
    }

    #[test]
    fn empty_scope_plans_are_scalars() {
        let t = table(vec![], vec![], 5);
        let sep = PotentialTable::scalar(1.0);
        let plan = plan_for(&t, &sep);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.sep_len(), 1);
        let mut out = vec![0.0; 1];
        marginalize_into(&plan, t.data(), &mut out, &mut []);
        assert_eq!(out[0], t.data()[0]);
    }

    #[test]
    fn span_scan_covers_all_runs() {
        let t = table(vec![0, 1, 2], vec![3, 2, 4], 6);
        let sep = t.marginalize_keep(&[1], IndexMode::Odometer);
        let plan = plan_for(&t, &sep);
        // Stitch the scan from several spans; must equal the full scan.
        let mut full: Vec<(usize, usize)> = Vec::new();
        let mut digits = vec![0usize; plan.arity()];
        plan.for_runs(&mut digits, |i, is| full.push((i, is)));
        let mut stitched: Vec<(usize, usize)> = Vec::new();
        for (lo, hi) in [(0usize, 2usize), (2, 3), (3, 6)] {
            plan.for_runs_span(lo, hi, |i, is| stitched.push((i, is)));
        }
        assert_eq!(full, stitched);
    }

    #[test]
    fn arena_layout_disjoint_and_counted() {
        // Synthetic plans via a tiny chain: 0-1-2 cliques.
        let cliques = vec![vec![0, 1], vec![1, 2], vec![2, 3]];
        let separators = vec![vec![], vec![1], vec![2]];
        let parent = vec![0, 0, 1];
        let children = vec![vec![1], vec![2], vec![]];
        let levels = vec![vec![0], vec![1], vec![2]];
        let cards = vec![2usize, 3, 2, 2];
        let plans =
            KernelPlans::build(&cliques, &separators, &parent, &children, &levels, 0, &cards);
        let layout = ArenaLayout::build(&plans, 0);
        // Edge 1: sep {1} len 3; edge 2: sep {2} len 2 → 2*(3+2) = 10.
        assert_eq!(layout.total, 10);
        let mut arena = TableArena::new();
        arena.ensure(layout.total);
        assert_eq!(arena.allocations(), 1);
        arena.ensure(layout.total);
        assert_eq!(arena.allocations(), 1, "steady state must not allocate");
        let (a, b) = arena.two_regions_mut(
            (layout.slots[1].msg, 3),
            (layout.slots[1].ratio, 3),
        );
        a[0] = 1.0;
        b[0] = 2.0;
        assert_eq!(arena.region(layout.slots[1].msg, 1)[0], 1.0);
        assert_eq!(arena.region(layout.slots[1].ratio, 1)[0], 2.0);
    }

    #[test]
    fn schedule_lists_only_parents() {
        let cliques = vec![vec![0, 1], vec![1, 2], vec![2, 3]];
        let separators = vec![vec![], vec![1], vec![2]];
        let parent = vec![0, 0, 1];
        let children = vec![vec![1], vec![2], vec![]];
        let levels = vec![vec![0], vec![1], vec![2]];
        let cards = vec![2usize, 2, 2, 2];
        let plans =
            KernelPlans::build(&cliques, &separators, &parent, &children, &levels, 0, &cards);
        assert_eq!(plans.schedule.active_parents, vec![vec![0], vec![1], vec![]]);
        // All-binary chain: both non-root edges carry a card-2 separator.
        assert_eq!(plans.msg(1).sep_len, 2);
        assert_eq!(plans.msg(2).sep_len, 2);
    }

    #[test]
    fn kernel_mode_parse_roundtrip() {
        assert_eq!(KernelMode::parse("fused"), Some(KernelMode::Fused));
        assert_eq!(KernelMode::parse("classic"), Some(KernelMode::Classic));
        assert_eq!(KernelMode::parse("batched"), Some(KernelMode::Batched));
        assert_eq!(KernelMode::parse("nope"), None);
        assert_eq!(KernelMode::Fused.label(), "fused");
        assert_eq!(KernelMode::default(), KernelMode::Fused);
        // FromStr and parse agree on every spelling, and the SPELLINGS
        // string enumerates exactly ALL — the consolidation contract.
        for m in KernelMode::ALL {
            assert_eq!(m.as_str().parse::<KernelMode>(), Ok(m));
            assert_eq!(m.label(), m.as_str());
            assert!(KernelMode::SPELLINGS.split('|').any(|s| s == m.as_str()));
        }
        assert_eq!(KernelMode::SPELLINGS.split('|').count(), KernelMode::ALL.len());
        assert!("simd".parse::<KernelMode>().is_err());
    }

    #[test]
    fn padded_lanes_rounds_to_simd_width() {
        assert_eq!(padded_lanes(0), 0);
        assert_eq!(padded_lanes(1), SIMD_WIDTH);
        assert_eq!(padded_lanes(SIMD_WIDTH), SIMD_WIDTH);
        assert_eq!(padded_lanes(SIMD_WIDTH + 1), 2 * SIMD_WIDTH);
        assert_eq!(padded_lanes(33), 40);
    }

    #[test]
    fn edge_intra_threshold_env_override_and_clamp() {
        // Without the env override the derived threshold stays inside the
        // clamp band whatever the machine's timer says.
        if intra_len_override().is_none() {
            let t = edge_intra_min_len(4);
            assert!((INTRA_LEN_CLAMP.0..=INTRA_LEN_CLAMP.1).contains(&t));
            // Shorter inner runs cost more per entry → threshold can only
            // drop (or hit the same clamp edge).
            assert!(edge_intra_min_len(1) <= edge_intra_min_len(1 << 20));
        } else {
            // Override pinned (e.g. CI sets FASTPGM_INTRA_MIN_LEN):
            // every edge sees the pinned value.
            assert_eq!(edge_intra_min_len(1), edge_intra_min_len(1 << 20));
        }
    }

    /// Stack B randomized lane copies of a table (index-major SoA).
    fn stack(tables: &[PotentialTable], lanes: usize) -> Vec<f64> {
        let len = tables[0].len();
        let mut buf = vec![0.0; len * lanes];
        for (b, t) in tables.iter().enumerate() {
            for (i, &x) in t.data().iter().enumerate() {
                buf[i * lanes + b] = x;
            }
        }
        buf
    }

    #[test]
    fn batched_kernels_match_scalar_per_lane() {
        let b = 5;
        let lanes = padded_lanes(b);
        let cliques: Vec<PotentialTable> =
            (0..b as u64).map(|s| table(vec![0, 2, 5, 6], vec![2, 3, 2, 4], 10 + s)).collect();
        for keep in [vec![], vec![2, 6], vec![6], vec![0, 2, 5, 6]] {
            let sep = cliques[0].marginalize_keep(&keep, IndexMode::Odometer);
            let plan = plan_for(&cliques[0], &sep);
            let src = stack(&cliques, lanes);
            let mut out = vec![0.0; sep.len() * lanes];
            let mut digits = vec![0usize; plan.arity()];
            marginalize_batch_into(&plan, &src, &mut out, lanes, &mut digits);
            for (lane, t) in cliques.iter().enumerate() {
                let mut scalar = vec![0.0; sep.len()];
                marginalize_into(&plan, t.data(), &mut scalar, &mut digits);
                for (i, &e) in scalar.iter().enumerate() {
                    assert_eq!(out[i * lanes + lane], e, "keep {keep:?} lane {lane}");
                }
            }
            // Absorb: multiply a stacked ratio back into the cliques.
            let ratios: Vec<PotentialTable> =
                (0..b as u64).map(|s| table(sep.vars().to_vec(), sep.cards().to_vec(), 30 + s)).collect();
            let ratio = stack(&ratios, lanes);
            let mut dst = stack(&cliques, lanes);
            absorb_batch_into(&plan, &ratio, &mut dst, lanes, &mut digits);
            for lane in 0..b {
                let mut scalar = cliques[lane].data().to_vec();
                absorb_into(&plan, ratios[lane].data(), &mut scalar, &mut digits);
                for (i, &e) in scalar.iter().enumerate() {
                    assert_eq!(dst[i * lanes + lane], e, "keep {keep:?} lane {lane}");
                }
            }
        }
    }

    #[test]
    fn batch_layout_regions_disjoint_and_steady_state() {
        let cliques = vec![vec![0, 1], vec![1, 2], vec![2, 3]];
        let separators = vec![vec![], vec![1], vec![2]];
        let parent = vec![0, 0, 1];
        let children = vec![vec![1], vec![2], vec![]];
        let levels = vec![vec![0], vec![1], vec![2]];
        let cards = vec![2usize, 3, 2, 2];
        let plans =
            KernelPlans::build(&cliques, &separators, &parent, &children, &levels, 0, &cards);
        let clique_lens = vec![2 * 3, 3 * 2, 2 * 2];
        let lanes = padded_lanes(3);
        let layout = BatchLayout::build(&plans, &clique_lens, lanes);
        // Cliques, then seps, then msg+ratio — all ascending and disjoint.
        let mut spans: Vec<(usize, usize)> = Vec::new();
        for (c, &off) in layout.clique.iter().enumerate() {
            spans.push((off, clique_lens[c] * lanes));
        }
        for c in [1usize, 2] {
            let sl = plans.msg(c).sep_len * lanes;
            spans.push((layout.sep[c], sl));
            spans.push((layout.slots[c].msg, sl));
            spans.push((layout.slots[c].ratio, sl));
        }
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "batch regions overlap: {spans:?}");
        }
        assert_eq!(layout.total, spans.last().map(|&(o, l)| o + l).unwrap());
        let mut arena = TableArena::new();
        arena.ensure(layout.total);
        arena.ensure(layout.total);
        assert_eq!(arena.allocations(), 1, "steady state must not allocate");
        // Three-way borrow of sep/msg/ratio works on the batched triple.
        let sl = plans.msg(1).sep_len * lanes;
        let (a, b, c) = arena.three_regions_mut(
            (layout.sep[1], sl),
            (layout.slots[1].msg, sl),
            (layout.slots[1].ratio, sl),
        );
        a[0] = 1.0;
        b[0] = 2.0;
        c[0] = 3.0;
        assert_eq!(arena.region(layout.sep[1], 1)[0], 1.0);
        assert_eq!(arena.region(layout.slots[1].msg, 1)[0], 2.0);
        assert_eq!(arena.region(layout.slots[1].ratio, 1)[0], 3.0);
    }
}
