//! Potential-table algebra: product, marginalization, division.
//!
//! Every operation exists in two index strategies:
//!
//! * [`IndexMode::Odometer`] — the optimized path enabled by canonical
//!   (sorted-scope) tables: one linear pass over the largest table,
//!   maintaining the flat index of every other table incrementally as
//!   mixed-radix digits advance. No divide/modulo in the loop; memory
//!   access over the big table is perfectly sequential. This is the
//!   reproduction of the paper's potential-table reorganization (opt v).
//! * [`IndexMode::NaiveDecode`] — the ablation baseline: decode each flat
//!   index with divide/modulo and re-encode per operand, the way a
//!   scope-order-agnostic implementation must.
//!
//! Bench E4 (`benches/bench_exact_ablation.rs`) measures the gap.

use super::PotentialTable;
use crate::core::VarId;

/// Index-mapping strategy for table operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum IndexMode {
    /// Incremental odometer index maintenance (optimized, default).
    #[default]
    Odometer,
    /// Per-entry divide/modulo decoding (ablation baseline).
    NaiveDecode,
}

/// Union of two sorted scopes, with per-scope cardinalities.
fn union_scope(
    a: &PotentialTable,
    b: &PotentialTable,
) -> (Vec<VarId>, Vec<usize>) {
    let (av, bv) = (a.vars(), b.vars());
    let mut vars = Vec::with_capacity(av.len() + bv.len());
    let mut cards = Vec::with_capacity(av.len() + bv.len());
    let (mut i, mut j) = (0, 0);
    while i < av.len() || j < bv.len() {
        if j >= bv.len() || (i < av.len() && av[i] < bv[j]) {
            vars.push(av[i]);
            cards.push(a.cards()[i]);
            i += 1;
        } else if i >= av.len() || bv[j] < av[i] {
            vars.push(bv[j]);
            cards.push(b.cards()[j]);
            j += 1;
        } else {
            assert_eq!(
                a.cards()[i],
                b.cards()[j],
                "cardinality mismatch for shared variable {}",
                av[i]
            );
            vars.push(av[i]);
            cards.push(a.cards()[i]);
            i += 1;
            j += 1;
        }
    }
    (vars, cards)
}

/// For each variable of `scope`, the stride it has in `t` (0 when absent).
fn mapped_strides(scope: &[VarId], t: &PotentialTable) -> Vec<usize> {
    scope
        .iter()
        .map(|&v| t.var_position(v).map_or(0, |p| t.strides()[p]))
        .collect()
}

/// Advance mixed-radix `digits` by one and incrementally update each mapped
/// flat index in `idxs` (one per strides slice in `maps`).
#[inline]
fn advance_mapped(
    digits: &mut [usize],
    cards: &[usize],
    maps: &[&[usize]],
    idxs: &mut [usize],
) {
    for pos in (0..digits.len()).rev() {
        digits[pos] += 1;
        if digits[pos] < cards[pos] {
            for (k, m) in maps.iter().enumerate() {
                idxs[k] += m[pos];
            }
            return;
        }
        digits[pos] = 0;
        for (k, m) in maps.iter().enumerate() {
            idxs[k] -= m[pos] * (cards[pos] - 1);
        }
    }
}

/// Drive a scan over all entries of a table with shape `cards`, split into
/// `outer` odometer steps × a contiguous `inner` run over the last axis.
///
/// `run(i, idxs)` processes entries `i .. i + inner` (contiguous in the
/// driving table); `idxs` holds the mapped flat index of each auxiliary
/// table *at the start of the run*, and the per-entry step of auxiliary
/// `k` within the run is `maps[k][last]`. Hoisting the last axis out of
/// the digit bookkeeping removes the branchy advance from the hot loop —
/// the main lever of the paper's optimization (v) beyond canonical order.
#[inline]
fn scan_outer_inner(
    cards: &[usize],
    total: usize,
    maps: &[&[usize]],
    mut run: impl FnMut(usize, &[usize]),
) {
    let k = cards.len();
    if k == 0 {
        run(0, &vec![0usize; maps.len()]);
        return;
    }
    let inner = cards[k - 1];
    let outer = total / inner;
    let outer_cards = &cards[..k - 1];
    let mut digits = vec![0usize; k.saturating_sub(1)];
    let mut idxs = vec![0usize; maps.len()];
    let mut i = 0usize;
    for _ in 0..outer {
        run(i, &idxs);
        i += inner;
        // Advance the outer digits only.
        for pos in (0..outer_cards.len()).rev() {
            digits[pos] += 1;
            if digits[pos] < outer_cards[pos] {
                for (m, idx) in maps.iter().zip(idxs.iter_mut()) {
                    *idx += m[pos];
                }
                break;
            }
            digits[pos] = 0;
            for (m, idx) in maps.iter().zip(idxs.iter_mut()) {
                *idx -= m[pos] * (outer_cards[pos] - 1);
            }
        }
    }
}

impl PotentialTable {
    /// Pointwise product over the union scope.
    pub fn product(&self, other: &PotentialTable, mode: IndexMode) -> PotentialTable {
        let (vars, cards) = union_scope(self, other);
        let mut out = PotentialTable::zeros(vars, cards);
        let ma = mapped_strides(out.vars(), self);
        let mb = mapped_strides(out.vars(), other);
        match mode {
            IndexMode::Odometer => {
                let n = out.len();
                let last = out.cards().len().saturating_sub(1);
                let (sa, sb) = if out.cards().is_empty() {
                    (0, 0)
                } else {
                    (ma[last], mb[last])
                };
                let a_data = self.data();
                let b_data = other.data();
                // Split borrow: `cards` (read) and `data` (write) are
                // disjoint fields of `out`, so neither needs a copy.
                let PotentialTable { cards, data: out_data, .. } = &mut out;
                let inner = if cards.is_empty() { 1 } else { cards[last] };
                // SAFETY of indexing: scan_outer_inner enumerates exactly
                // the mixed-radix index space of `out`.
                scan_outer_inner(cards, n, &[&ma, &mb], |i, idxs| {
                    let (mut ia, mut ib) = (idxs[0], idxs[1]);
                    for slot in &mut out_data[i..i + inner] {
                        *slot = a_data[ia] * b_data[ib];
                        ia += sa;
                        ib += sb;
                    }
                });
            }
            IndexMode::NaiveDecode => {
                let mut digits = vec![0usize; out.vars().len()];
                for i in 0..out.len() {
                    out.digits_of(i, &mut digits);
                    let ia: usize =
                        digits.iter().zip(&ma).map(|(&d, &s)| d * s).sum();
                    let ib: usize =
                        digits.iter().zip(&mb).map(|(&d, &s)| d * s).sum();
                    out.data_mut()[i] = self.data()[ia] * other.data()[ib];
                }
            }
        }
        out
    }

    /// Marginalize down to `keep ∩ scope` (sum out everything else).
    /// `keep` must be sorted.
    pub fn marginalize_keep(&self, keep: &[VarId], mode: IndexMode) -> PotentialTable {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]));
        let (vars, cards): (Vec<VarId>, Vec<usize>) = self
            .vars()
            .iter()
            .zip(self.cards())
            .filter(|(v, _)| keep.binary_search(v).is_ok())
            .map(|(&v, &c)| (v, c))
            .unzip();
        let mut out = PotentialTable::zeros(vars, cards);
        let mo = mapped_strides(self.vars(), &out);
        match mode {
            IndexMode::Odometer => {
                let cards = self.cards();
                let last = cards.len().saturating_sub(1);
                let so = if cards.is_empty() { 0 } else { mo[last] };
                let inner = if cards.is_empty() { 1 } else { cards[last] };
                let src = self.data();
                let out_data = out.data_mut();
                scan_outer_inner(cards, src.len(), &[&mo], |i, idxs| {
                    let mut io = idxs[0];
                    if so == 0 {
                        // Last axis is summed out: accumulate the run into
                        // one output cell (tight reduction loop).
                        let mut acc = 0.0;
                        for &x in &src[i..i + inner] {
                            acc += x;
                        }
                        out_data[io] += acc;
                    } else {
                        for &x in &src[i..i + inner] {
                            out_data[io] += x;
                            io += so;
                        }
                    }
                });
            }
            IndexMode::NaiveDecode => {
                let mut digits = vec![0usize; self.vars().len()];
                for i in 0..self.len() {
                    self.digits_of(i, &mut digits);
                    let io: usize =
                        digits.iter().zip(&mo).map(|(&d, &s)| d * s).sum();
                    out.data_mut()[io] += self.data()[i];
                }
            }
        }
        out
    }

    /// Sum out a single variable.
    pub fn marginalize_out(&self, var: VarId, mode: IndexMode) -> PotentialTable {
        let keep: Vec<VarId> =
            self.vars().iter().copied().filter(|&v| v != var).collect();
        self.marginalize_keep(&keep, mode)
    }

    /// In-place multiply by a table whose scope is a subset of ours
    /// (the junction-tree "absorb" hot path).
    pub fn multiply_subset(&mut self, sub: &PotentialTable, mode: IndexMode) {
        debug_assert!(sub.vars().iter().all(|&v| self.contains_var(v)));
        let ms = mapped_strides(self.vars(), sub);
        match mode {
            IndexMode::Odometer => {
                // Split borrows instead of per-call copies: `sub` is a
                // distinct table (the `&mut self` receiver rules out
                // aliasing), and `cards` (read) and `data` (write) are
                // disjoint fields of `self`. The absorb hot path used to
                // clone `sub.data()` on every call.
                let sub_data = sub.data();
                let PotentialTable { cards, data, .. } = self;
                let last = cards.len().saturating_sub(1);
                let ss = if cards.is_empty() { 0 } else { ms[last] };
                let inner = if cards.is_empty() { 1 } else { cards[last] };
                let n = data.len();
                scan_outer_inner(cards, n, &[&ms], |i, idxs| {
                    let mut is = idxs[0];
                    if ss == 0 {
                        // Subset doesn't span the last axis: one multiplier
                        // for the whole contiguous run.
                        let v = sub_data[is];
                        for x in &mut data[i..i + inner] {
                            *x *= v;
                        }
                    } else {
                        for x in &mut data[i..i + inner] {
                            *x *= sub_data[is];
                            is += ss;
                        }
                    }
                });
            }
            IndexMode::NaiveDecode => {
                let mut digits = vec![0usize; self.vars().len()];
                for i in 0..self.len() {
                    self.digits_of(i, &mut digits);
                    let is: usize =
                        digits.iter().zip(&ms).map(|(&d, &s)| d * s).sum();
                    self.data_mut()[i] *= sub.data()[is];
                }
            }
        }
    }

    /// In-place divide by a subset-scope table, with the junction-tree
    /// convention `0 / 0 = 0`.
    pub fn divide_subset(&mut self, sub: &PotentialTable, mode: IndexMode) {
        debug_assert!(sub.vars().iter().all(|&v| self.contains_var(v)));
        let ms = mapped_strides(self.vars(), sub);
        let div = |num: f64, den: f64| if den == 0.0 { 0.0 } else { num / den };
        match mode {
            IndexMode::Odometer => {
                // Same split-borrow shape as `multiply_subset`: no copies.
                let sub_data = sub.data();
                let PotentialTable { cards, data, .. } = self;
                let last = cards.len().saturating_sub(1);
                let ss = if cards.is_empty() { 0 } else { ms[last] };
                let inner = if cards.is_empty() { 1 } else { cards[last] };
                let n = data.len();
                scan_outer_inner(cards, n, &[&ms], |i, idxs| {
                    let mut is = idxs[0];
                    if ss == 0 {
                        let den = sub_data[is];
                        if den == 0.0 {
                            for x in &mut data[i..i + inner] {
                                *x = 0.0;
                            }
                        } else {
                            let inv = 1.0 / den;
                            for x in &mut data[i..i + inner] {
                                *x *= inv;
                            }
                        }
                    } else {
                        for x in &mut data[i..i + inner] {
                            *x = div(*x, sub_data[is]);
                            is += ss;
                        }
                    }
                });
            }
            IndexMode::NaiveDecode => {
                let mut digits = vec![0usize; self.vars().len()];
                for i in 0..self.len() {
                    self.digits_of(i, &mut digits);
                    let is: usize =
                        digits.iter().zip(&ms).map(|(&d, &s)| d * s).sum();
                    self.data_mut()[i] = div(self.data()[i], sub.data()[is]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(vars: Vec<VarId>, cards: Vec<usize>, seed: u64) -> PotentialTable {
        // Deterministic pseudo-random positive entries.
        let mut t = PotentialTable::zeros(vars, cards);
        let mut s = seed;
        for x in t.data_mut() {
            *x = (crate::rng::splitmix64(&mut s) % 1000) as f64 / 100.0 + 0.01;
        }
        t
    }

    #[test]
    fn product_disjoint_scopes() {
        let a = PotentialTable::from_data(vec![0], vec![2], vec![2.0, 3.0]);
        let b = PotentialTable::from_data(vec![1], vec![2], vec![5.0, 7.0]);
        let p = a.product(&b, IndexMode::Odometer);
        assert_eq!(p.vars(), &[0, 1]);
        assert_eq!(p.data(), &[10.0, 14.0, 15.0, 21.0]);
    }

    #[test]
    fn product_shared_var() {
        let a = PotentialTable::from_data(vec![0, 1], vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = PotentialTable::from_data(vec![1], vec![2], vec![10.0, 100.0]);
        let p = a.product(&b, IndexMode::Odometer);
        assert_eq!(p.vars(), &[0, 1]);
        assert_eq!(p.data(), &[10.0, 200.0, 30.0, 400.0]);
    }

    #[test]
    fn product_modes_agree() {
        let a = table(vec![0, 2, 5], vec![2, 3, 2], 1);
        let b = table(vec![1, 2], vec![4, 3], 2);
        let p1 = a.product(&b, IndexMode::Odometer);
        let p2 = a.product(&b, IndexMode::NaiveDecode);
        assert_eq!(p1.vars(), &[0, 1, 2, 5]);
        for (x, y) in p1.data().iter().zip(p2.data()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn product_commutes() {
        let a = table(vec![0, 3], vec![3, 2], 3);
        let b = table(vec![1, 3], vec![2, 2], 4);
        let p1 = a.product(&b, IndexMode::Odometer);
        let p2 = b.product(&a, IndexMode::Odometer);
        assert_eq!(p1, p2);
    }

    #[test]
    fn marginalize_matches_manual() {
        let a = PotentialTable::from_data(vec![0, 1], vec![2, 3],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = a.marginalize_keep(&[0], IndexMode::Odometer);
        assert_eq!(m.vars(), &[0]);
        assert_eq!(m.data(), &[6.0, 15.0]);
        let m1 = a.marginalize_keep(&[1], IndexMode::Odometer);
        assert_eq!(m1.data(), &[5.0, 7.0, 9.0]);
    }

    #[test]
    fn marginalize_modes_agree() {
        let a = table(vec![1, 4, 6, 7], vec![2, 3, 2, 2], 5);
        let k = vec![1, 6];
        let m1 = a.marginalize_keep(&k, IndexMode::Odometer);
        let m2 = a.marginalize_keep(&k, IndexMode::NaiveDecode);
        for (x, y) in m1.data().iter().zip(m2.data()) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn marginalize_preserves_mass() {
        let a = table(vec![0, 1, 2], vec![3, 2, 4], 6);
        let m = a.marginalize_keep(&[1], IndexMode::Odometer);
        assert!((m.sum() - a.sum()).abs() < 1e-9);
        let empty = a.marginalize_keep(&[], IndexMode::Odometer);
        assert_eq!(empty.len(), 1);
        assert!((empty.sum() - a.sum()).abs() < 1e-9);
    }

    #[test]
    fn marginalize_out_then_product_roundtrip_shape() {
        let a = table(vec![0, 1], vec![2, 2], 7);
        let m = a.marginalize_out(1, IndexMode::Odometer);
        assert_eq!(m.vars(), &[0]);
    }

    #[test]
    fn multiply_subset_matches_product() {
        let mut a = table(vec![0, 1, 2], vec![2, 2, 3], 8);
        let sub = table(vec![1], vec![2], 9);
        let expect = a.product(&sub, IndexMode::Odometer);
        a.multiply_subset(&sub, IndexMode::Odometer);
        for (x, y) in a.data().iter().zip(expect.data()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn multiply_subset_modes_agree() {
        let base = table(vec![0, 2, 3], vec![2, 3, 2], 10);
        let sub = table(vec![0, 3], vec![2, 2], 11);
        let mut a = base.clone();
        let mut b = base.clone();
        a.multiply_subset(&sub, IndexMode::Odometer);
        b.multiply_subset(&sub, IndexMode::NaiveDecode);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn divide_subset_modes_agree() {
        // Regression for the split-borrow rewrite (the Odometer arms used
        // to copy `sub.data()` per call): both index modes must agree for
        // several subset positions, including 0-denominator cells.
        let base = table(vec![0, 2, 3, 5], vec![2, 3, 2, 2], 15);
        for sub_vars in [vec![0], vec![2, 5], vec![0, 3], vec![5]] {
            let cards: Vec<usize> =
                sub_vars.iter().map(|&v| base.card_of(v).unwrap()).collect();
            let mut sub = table(sub_vars.clone(), cards, 16);
            sub.data_mut()[0] = 0.0;
            let mut a = base.clone();
            let mut b = base.clone();
            a.divide_subset(&sub, IndexMode::Odometer);
            b.divide_subset(&sub, IndexMode::NaiveDecode);
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-12, "sub {sub_vars:?}");
            }
            let mut m1 = base.clone();
            let mut m2 = base.clone();
            m1.multiply_subset(&sub, IndexMode::Odometer);
            m2.multiply_subset(&sub, IndexMode::NaiveDecode);
            for (x, y) in m1.data().iter().zip(m2.data()) {
                assert!((x - y).abs() < 1e-12, "sub {sub_vars:?}");
            }
        }
    }

    #[test]
    fn divide_inverts_multiply() {
        let mut a = table(vec![0, 1], vec![2, 3], 12);
        let orig = a.clone();
        let sub = table(vec![1], vec![3], 13);
        a.multiply_subset(&sub, IndexMode::Odometer);
        a.divide_subset(&sub, IndexMode::Odometer);
        for (x, y) in a.data().iter().zip(orig.data()) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn divide_zero_by_zero_is_zero() {
        let mut a = PotentialTable::from_data(vec![0], vec![2], vec![0.0, 4.0]);
        let sub = PotentialTable::from_data(vec![0], vec![2], vec![0.0, 2.0]);
        a.divide_subset(&sub, IndexMode::Odometer);
        assert_eq!(a.data(), &[0.0, 2.0]);
    }

    #[test]
    fn product_with_scalar_identity() {
        let a = table(vec![2, 4], vec![2, 2], 14);
        let one = PotentialTable::scalar(1.0);
        let p = a.product(&one, IndexMode::Odometer);
        assert_eq!(p, a);
    }

    #[test]
    fn product_associative() {
        let a = table(vec![0], vec![2], 20);
        let b = table(vec![1], vec![3], 21);
        let c = table(vec![0, 2], vec![2, 2], 22);
        let p1 = a.product(&b, IndexMode::Odometer).product(&c, IndexMode::Odometer);
        let p2 = a.product(&b.product(&c, IndexMode::Odometer), IndexMode::Odometer);
        assert_eq!(p1.vars(), p2.vars());
        for (x, y) in p1.data().iter().zip(p2.data()) {
            assert!((x - y).abs() < 1e-9);
        }
    }
}
