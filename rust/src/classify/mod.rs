//! Classification with Bayesian networks (paper §2: "the integration of
//! these key tasks also results in a complete process of classification").
//!
//! Train: learn structure (PC-stable) + parameters (MLE) from labeled
//! data — or accept a known structure. Predict: posterior of the class
//! variable given the feature evidence, via any [`InferenceEngine`].
//!
//! Training routes every count through one shared
//! [`crate::counts::CountCache`]: the contingency tables the PC phase
//! builds for its CI tests stay resident, so the MLE phase hits or
//! subset-projects instead of rescanning the training rows.

use crate::core::{Dataset, Evidence, VarId};
use crate::counts::CountCache;
use crate::graph::Dag;
use crate::inference::exact::JunctionTree;
use crate::inference::InferenceEngine;
use crate::metrics;
use crate::network::BayesianNetwork;
use crate::parameter::{mle_with_cache, MleOptions};
use crate::structure::{pc_stable_with_cache, PcOptions};

/// How the classifier obtains its structure.
#[derive(Clone, Debug)]
pub enum StructureSource {
    /// Learn with PC-stable from the training data.
    Learn(PcOptions),
    /// Use a fixed DAG.
    Fixed(Dag),
    /// Naive Bayes: class is the sole parent of every feature.
    NaiveBayes,
}

/// A trained Bayesian-network classifier.
pub struct BnClassifier {
    pub net: BayesianNetwork,
    pub class_var: VarId,
}

impl BnClassifier {
    /// Train on a dataset whose `class_var` column holds the labels.
    pub fn train(
        data: &Dataset,
        class_var: VarId,
        source: StructureSource,
        mle_opts: &MleOptions,
    ) -> Self {
        // One cache across both phases: PC's CI tables feed MLE's
        // family counts (hits / subset projections, never a rescan of
        // an already-counted scope).
        let counts = CountCache::new();
        let dag = match source {
            StructureSource::Fixed(d) => d,
            StructureSource::NaiveBayes => {
                let mut d = Dag::new(data.n_vars());
                for v in 0..data.n_vars() {
                    if v != class_var {
                        d.add_edge(class_var, v);
                    }
                }
                d
            }
            StructureSource::Learn(pc_opts) => {
                let result = pc_stable_with_cache(data, &pc_opts, &counts);
                // A CPDAG must be extended to a DAG to parameterize;
                // fall back to naive Bayes augmentation if extension fails
                // (possible on small samples with conflicting colliders).
                match result.graph.to_dag() {
                    Some(d) => d,
                    None => {
                        let mut d = Dag::new(data.n_vars());
                        for v in 0..data.n_vars() {
                            if v != class_var {
                                d.add_edge(class_var, v);
                            }
                        }
                        d
                    }
                }
            }
        };
        let net = mle_with_cache(data, &dag, mle_opts, &counts);
        BnClassifier { net, class_var }
    }

    /// Posterior over classes for one feature row (class column ignored).
    pub fn posterior(&self, row: &[u8]) -> Vec<f64> {
        let ev: Evidence = (0..self.net.n_vars())
            .filter(|&v| v != self.class_var)
            .map(|v| (v, row[v] as usize))
            .collect();
        let jt = JunctionTree::build(&self.net);
        let mut eng = jt.engine();
        eng.query(self.class_var, &ev)
    }

    /// Predict labels for a whole dataset with a reusable engine (builds
    /// the junction tree once).
    pub fn predict(&self, data: &Dataset) -> Vec<usize> {
        let jt = JunctionTree::build(&self.net);
        let mut eng = jt.engine();
        (0..data.n_rows())
            .map(|r| {
                let ev: Evidence = (0..data.n_vars())
                    .filter(|&v| v != self.class_var)
                    .map(|v| (v, data.value(r, v)))
                    .collect();
                let post = eng.query(self.class_var, &ev);
                argmax(&post)
            })
            .collect()
    }

    /// Accuracy on a labeled dataset.
    pub fn evaluate(&self, data: &Dataset) -> f64 {
        let preds = self.predict(data);
        let pairs: Vec<(usize, usize)> = preds
            .into_iter()
            .enumerate()
            .map(|(r, p)| (p, data.value(r, self.class_var)))
            .collect();
        metrics::accuracy(&pairs)
    }
}

/// Index of the largest element (first on ties).
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[0.5, 0.5]), 0);
    }

    #[test]
    fn naive_bayes_beats_chance_on_asia() {
        // Predict "bronc" from the other 7 variables.
        let net = repository::asia();
        let class_var = net.var_index("bronc").unwrap();
        let mut rng = Pcg::seed_from(21);
        let data = forward_sample_dataset(&net, 8_000, &mut rng);
        let (train, test) = data.split(0.8);
        let clf = BnClassifier::train(
            &train,
            class_var,
            StructureSource::NaiveBayes,
            &MleOptions::default(),
        );
        let acc = clf.evaluate(&test);
        // Base rate P(bronc=no) = 0.55; the features are informative.
        assert!(acc > 0.6, "accuracy = {acc}");
    }

    #[test]
    fn true_structure_at_least_as_good() {
        let net = repository::cancer();
        let class_var = net.var_index("cancer").unwrap();
        let mut rng = Pcg::seed_from(22);
        let data = forward_sample_dataset(&net, 6_000, &mut rng);
        let (train, test) = data.split(0.8);
        let fixed = BnClassifier::train(
            &train,
            class_var,
            StructureSource::Fixed(net.dag().clone()),
            &MleOptions::default(),
        );
        let acc = fixed.evaluate(&test);
        // Cancer is heavily skewed (P(cancer) ≈ 1.2%); accuracy must at
        // least match the majority class.
        assert!(acc >= 0.95, "accuracy = {acc}");
    }

    #[test]
    fn learned_structure_pipeline_runs() {
        let net = repository::sprinkler();
        let mut rng = Pcg::seed_from(23);
        let data = forward_sample_dataset(&net, 4_000, &mut rng);
        let clf = BnClassifier::train(
            &data,
            3,
            StructureSource::Learn(PcOptions::default()),
            &MleOptions::default(),
        );
        let post = clf.posterior(&[1, 0, 1, 0]);
        assert_eq!(post.len(), 2);
        assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }
}
