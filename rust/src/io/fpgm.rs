//! The native `.fpgm` network format.
//!
//! A deliberately trivial line-based text format so the Rust runtime and
//! the Python compile path (`python/compile/networks.py`) can share one
//! parser-friendly artifact without a JSON dependency:
//!
//! ```text
//! fpgm 1
//! name <network-name>
//! var <name> <card> [state names...]
//! ...
//! parents <var-index> [parent indices...]
//! ...
//! cpt <var-index> <p0> <p1> ...      # row-major, last parent fastest
//! ...
//! end
//! ```
//!
//! Every `var` line precedes all `parents` lines, which precede all `cpt`
//! lines. Indices refer to `var` declaration order.
//!
//! ## Format v2: checksummed snapshots
//!
//! Version 2 (`fpgm 2`) is the same body followed by one trailer line:
//!
//! ```text
//! fpgm 2
//! ...same directives...
//! end
//! crc32 <8 hex digits>
//! ```
//!
//! The digest is CRC32 over the *canonical body* — the trimmed,
//! non-empty, non-comment lines from the header through `end`, joined
//! with `\n` plus a trailing `\n` — so it is stable across CRLF mangling
//! while still catching any single-byte damage to real content. A v2
//! file with no trailer is [`ModelError::Truncated`] (the signature of a
//! torn write); a digest mismatch is [`ModelError::Corrupt`]. v1 files
//! carry no trailer and still load.
//!
//! Decoding is **total**: [`decode`] parses into a raw form, runs
//! [`model::validate_raw`], and only then constructs — no corrupted
//! input can reach a panicking constructor. [`save_atomic`] writes
//! temp-file + fsync + rename so a crash leaves the previous snapshot
//! intact, and hosts the `truncate_model` fault site so chaos plans can
//! tear or bit-flip a snapshot deterministically.

use crate::faults::{FaultAction, FaultHook, FaultSite};
use crate::io::model::{self, ModelError, RawNet};
use crate::network::BayesianNetwork;
use anyhow::{Context, Result};

/// Digest and version of a decoded snapshot, for recovery logs and the
/// frontend's digest verification of a recovered model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotInfo {
    /// Format version (1 or 2).
    pub version: u8,
    /// CRC32 of the canonical body (computed for v1 too, for logging).
    pub digest: u32,
}

/// Serialize a network to `.fpgm` v1 text (the Python-interop format).
pub fn to_string(net: &BayesianNetwork) -> String {
    let mut out = String::from("fpgm 1\n");
    push_body(net, &mut out);
    out
}

/// Serialize to v2: versioned header plus CRC32 trailer.
pub fn to_string_v2(net: &BayesianNetwork) -> String {
    let mut out = String::from("fpgm 2\n");
    push_body(net, &mut out);
    let crc = model::crc32(out.as_bytes());
    out.push_str(&format!("crc32 {crc:08x}\n"));
    out
}

fn push_body(net: &BayesianNetwork, out: &mut String) {
    out.push_str(&format!("name {}\n", net.name()));
    for v in net.variables() {
        out.push_str(&format!("var {} {}", v.name, v.cardinality));
        for s in &v.states {
            out.push(' ');
            out.push_str(s);
        }
        out.push('\n');
    }
    for v in 0..net.n_vars() {
        out.push_str(&format!("parents {}", v));
        for &p in net.parents(v) {
            out.push_str(&format!(" {p}"));
        }
        out.push('\n');
    }
    for v in 0..net.n_vars() {
        out.push_str(&format!("cpt {}", v));
        for p in &net.cpt(v).table {
            out.push_str(&format!(" {p:.17}"));
        }
        out.push('\n');
    }
    out.push_str("end\n");
}

/// Total decoder for v1 and v2 text: parse → validate → construct.
/// Never panics or hangs, whatever the bytes; every failure is a typed
/// [`ModelError`].
pub fn decode(text: &str) -> Result<(BayesianNetwork, SnapshotInfo), ModelError> {
    // Canonical body: trimmed, non-empty, non-comment lines up to the
    // trailer (a line starting with "crc32"), which is kept separate.
    let mut body: Vec<&str> = Vec::new();
    let mut trailer: Option<&str> = None;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix("crc32") {
            trailer = Some(rest.trim());
            break;
        }
        body.push(line);
    }
    let header = *body
        .first()
        .ok_or_else(|| ModelError::Truncated("empty fpgm input".into()))?;
    let version: u8 = match header {
        "fpgm 1" => 1,
        "fpgm 2" => 2,
        other => {
            return Err(ModelError::Corrupt(format!(
                "unsupported fpgm header {other:?}"
            )))
        }
    };
    let mut canonical = body.join("\n");
    canonical.push('\n');
    let digest = model::crc32(canonical.as_bytes());
    if version == 2 {
        let stated = trailer.ok_or_else(|| {
            ModelError::Truncated("v2 snapshot has no crc32 trailer".into())
        })?;
        let stated = u32::from_str_radix(stated, 16).map_err(|e| {
            ModelError::Corrupt(format!("bad crc32 trailer {stated:?}: {e}"))
        })?;
        if stated != digest {
            return Err(ModelError::Corrupt(format!(
                "crc32 mismatch: trailer {stated:08x}, body {digest:08x}"
            )));
        }
    }
    let raw = parse_raw(&body[1..])?;
    let net = model::build(raw)?;
    Ok((net, SnapshotInfo { version, digest }))
}

/// Parse body lines (header already consumed) into an unvalidated
/// [`RawNet`]. Pure string work — no constructors, no asserts.
fn parse_raw(lines: &[&str]) -> Result<RawNet, ModelError> {
    let corrupt = |d: String| Err(ModelError::Corrupt(d));
    let mut raw = RawNet { name: "unnamed".into(), ..Default::default() };
    let mut tables: Vec<Option<Vec<f64>>> = Vec::new();
    let mut saw_end = false;
    for &line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("name") => {
                raw.name = it.collect::<Vec<_>>().join(" ");
            }
            Some("var") => {
                let vname = match it.next() {
                    Some(n) => n,
                    None => return corrupt("var line missing name".into()),
                };
                let card: usize = match it.next().map(str::parse) {
                    Some(Ok(c)) => c,
                    _ => {
                        return corrupt(format!("var {vname}: bad cardinality"))
                    }
                };
                let states: Vec<String> = it.map(String::from).collect();
                raw.variables.push((vname.to_string(), card, states));
                raw.parents.push(Vec::new());
                tables.push(None);
            }
            Some("parents") => {
                let v: usize = match it.next().map(str::parse) {
                    Some(Ok(v)) => v,
                    _ => return corrupt("parents line: bad index".into()),
                };
                if v >= raw.variables.len() {
                    return corrupt(format!("parents line: index {v} out of range"));
                }
                let mut ps = Vec::new();
                for tok in it {
                    match tok.parse::<usize>() {
                        Ok(p) => ps.push(p),
                        Err(e) => {
                            return corrupt(format!("bad parent index {tok:?}: {e}"))
                        }
                    }
                }
                raw.parents[v] = ps;
            }
            Some("cpt") => {
                let v: usize = match it.next().map(str::parse) {
                    Some(Ok(v)) => v,
                    _ => return corrupt("cpt line: bad index".into()),
                };
                if v >= raw.variables.len() {
                    return corrupt(format!("cpt line: index {v} out of range"));
                }
                let mut vals = Vec::new();
                for tok in it {
                    match tok.parse::<f64>() {
                        Ok(p) => vals.push(p),
                        Err(e) => {
                            return corrupt(format!("bad probability {tok:?}: {e}"))
                        }
                    }
                }
                tables[v] = Some(vals);
            }
            Some("end") => {
                saw_end = true;
                break;
            }
            Some(other) => {
                return corrupt(format!("unknown fpgm directive {other:?}"))
            }
            None => unreachable!("body lines are non-empty"),
        }
    }
    if !saw_end {
        return Err(ModelError::Truncated("fpgm input missing 'end'".into()));
    }
    raw.tables = tables
        .into_iter()
        .enumerate()
        .map(|(v, t)| {
            t.ok_or_else(|| {
                ModelError::Corrupt(format!("missing cpt for variable {v}"))
            })
        })
        .collect::<Result<_, _>>()?;
    Ok(raw)
}

/// Parse `.fpgm` text into a network (back-compat `anyhow` surface).
pub fn from_str(text: &str) -> Result<BayesianNetwork> {
    Ok(decode(text).map_err(anyhow::Error::from)?.0)
}

/// Write a network to a `.fpgm` file (v1 text, plain write — the
/// Python-interop path). Crash-safe snapshots use [`save_atomic`].
pub fn save(net: &BayesianNetwork, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_string(net))
        .with_context(|| format!("writing {}", path.display()))
}

/// Atomically write a v2 snapshot: temp file in the same directory,
/// fsync, rename over `path`. A crash at any point leaves either the
/// previous snapshot or a temp file the loader never looks at. The
/// `truncate_model` fault site lives here: a `kill`/`drop` rule tears
/// the payload in half (a simulated torn write), a `corrupt` rule flips
/// one deterministic bit — both are caught by the CRC trailer on load.
pub fn save_atomic(
    net: &BayesianNetwork,
    path: &std::path::Path,
    faults: &FaultHook,
) -> Result<SnapshotInfo> {
    use std::io::Write;

    let text = to_string_v2(net);
    let digest = model::crc32(
        text
            .rsplit_once("crc32")
            .map(|(body, _)| body)
            .unwrap_or(&text)
            .as_bytes(),
    );
    let mut bytes = text.into_bytes();
    if let Some(f) = faults {
        match f.decide(FaultSite::TruncateModel, None) {
            FaultAction::Kill | FaultAction::Drop => {
                bytes.truncate(bytes.len() / 2);
            }
            FaultAction::Corrupt => f.corrupt_bytes(&mut bytes),
            other => {
                if let Some(d) = other.sleep() {
                    std::thread::sleep(d);
                }
            }
        }
    }
    let tmp = path.with_file_name(format!(
        "{}.tmp",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("snapshot.fpgm")
    ));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path).with_context(|| {
        format!("renaming {} -> {}", tmp.display(), path.display())
    })?;
    // Best-effort directory sync so the rename itself is durable.
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(SnapshotInfo { version: 2, digest })
}

/// Load a network from a `.fpgm` file (v1 or v2, validated).
pub fn load(path: &std::path::Path) -> Result<BayesianNetwork> {
    Ok(load_snapshot(path)
        .map_err(anyhow::Error::from)
        .with_context(|| format!("loading {}", path.display()))?
        .0)
}

/// Typed load: read, decode, validate. Callers branch on the
/// [`ModelError`] variant to pick a recovery path.
pub fn load_snapshot(
    path: &std::path::Path,
) -> Result<(BayesianNetwork, SnapshotInfo), ModelError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ModelError::Io(format!("reading {}: {e}", path.display())))?;
    decode(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Evidence;
    use crate::network::repository;

    #[test]
    fn roundtrip_all_builtins() {
        for name in repository::BUILTIN_NAMES {
            let net = repository::by_name(name).unwrap();
            for text in [to_string(&net), to_string_v2(&net)] {
                let back = from_str(&text).unwrap();
                assert_eq!(back.name(), net.name());
                assert_eq!(back.n_vars(), net.n_vars());
                assert_eq!(back.dag().edges(), net.dag().edges());
                for v in 0..net.n_vars() {
                    assert_eq!(back.cpt(v).table, net.cpt(v).table, "{name} var {v}");
                    assert_eq!(back.variable(v).states, net.variable(v).states);
                }
            }
        }
    }

    #[test]
    fn roundtrip_preserves_inference() {
        let net = repository::asia();
        let back = from_str(&to_string(&net)).unwrap();
        let ev = Evidence::new().with(0, 1);
        for v in 0..net.n_vars() {
            let a = net.brute_force_posterior(v, &ev);
            let b = back.brute_force_posterior(v, &ev);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn v2_crc_catches_damage() {
        let net = repository::sprinkler();
        let text = to_string_v2(&net);
        let (_, info) = decode(&text).unwrap();
        assert_eq!(info.version, 2);
        // Flip one probability digit: body changes, trailer does not.
        let damaged = text.replacen("0.", "1.", 1);
        match decode(&damaged) {
            Err(ModelError::Corrupt(_)) | Err(ModelError::Invalid(_)) => {}
            other => panic!("damaged v2 decoded as {other:?}"),
        }
        // Drop the trailer: a torn write.
        let torn = &text[..text.rfind("crc32").unwrap()];
        assert!(matches!(decode(torn), Err(ModelError::Truncated(_))));
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str("").is_err());
        assert!(from_str("fpgm 3\nend\n").is_err());
        assert!(from_str("fpgm 1\nvar x 2\nend\n").is_err()); // missing cpt
        assert!(from_str("fpgm 1\nbogus\nend\n").is_err());
        assert!(from_str("fpgm 1\nvar x 2\ncpt 0 0.5 0.5\n").is_err()); // no end
        // Construction-precondition garbage must error, not panic.
        assert!(from_str("fpgm 1\nvar x 0\ncpt 0\nend\n").is_err()); // card 0
        assert!(from_str("fpgm 1\nvar x 2\nparents 0 0\ncpt 0 0.5 0.5\nend\n").is_err()); // self loop
        assert!(from_str("fpgm 1\nvar x 2\ncpt 0 NaN NaN\nend\n").is_err()); // NaN
        assert!(from_str("fpgm 1\nvar x 2\ncpt 0 0.9 0.9\nend\n").is_err()); // bad row
    }

    #[test]
    fn rejects_cycles() {
        let text = "fpgm 1\nname c\nvar a 2\nvar b 2\nparents 0 1\nparents 1 0\ncpt 0 0.5 0.5 0.5 0.5\ncpt 1 0.5 0.5 0.5 0.5\nend\n";
        assert!(from_str(text).is_err());
    }

    #[test]
    fn rejects_wrong_cpt_size() {
        let text = "fpgm 1\nvar a 2\nparents 0\ncpt 0 1.0\nend\n";
        assert!(from_str(text).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let net = repository::sprinkler();
        let mut text = String::from("# header comment\n\n");
        text.push_str(&to_string(&net));
        let back = from_str(&text).unwrap();
        assert_eq!(back.n_vars(), 4);
    }

    #[test]
    fn atomic_save_round_trips_and_faults_tear_it() {
        use crate::faults::FaultPlan;

        let dir = std::env::temp_dir().join("fastpgm_fpgm_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let net = repository::asia();

        let clean = dir.join("clean.fpgm");
        let info = save_atomic(&net, &clean, &None).unwrap();
        let (back, loaded) = load_snapshot(&clean).unwrap();
        assert_eq!(loaded, info);
        assert_eq!(back.n_vars(), net.n_vars());
        assert!(!clean.with_file_name("clean.fpgm.tmp").exists());

        // A kill rule at truncate_model tears the write in half; the
        // loader detects it as truncated/corrupt, never panics.
        let torn = dir.join("torn.fpgm");
        let faults =
            Some(FaultPlan::parse("seed=5,kill=1.0@truncate_model").unwrap().arm(None));
        save_atomic(&net, &torn, &faults).unwrap();
        assert!(load_snapshot(&torn).is_err());

        // A corrupt rule flips one bit; the CRC trailer catches it.
        let flipped = dir.join("flipped.fpgm");
        let faults =
            Some(FaultPlan::parse("seed=5,corrupt=1.0@truncate_model").unwrap().arm(None));
        save_atomic(&net, &flipped, &faults).unwrap();
        assert!(load_snapshot(&flipped).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
