//! The native `.fpgm` network format.
//!
//! A deliberately trivial line-based text format so the Rust runtime and
//! the Python compile path (`python/compile/networks.py`) can share one
//! parser-friendly artifact without a JSON dependency:
//!
//! ```text
//! fpgm 1
//! name <network-name>
//! var <name> <card> [state names...]
//! ...
//! parents <var-index> [parent indices...]
//! ...
//! cpt <var-index> <p0> <p1> ...      # row-major, last parent fastest
//! ...
//! end
//! ```
//!
//! Every `var` line precedes all `parents` lines, which precede all `cpt`
//! lines. Indices refer to `var` declaration order.

use crate::core::Variable;
use crate::graph::Dag;
use crate::network::{BayesianNetwork, Cpt};
use anyhow::{bail, Context, Result};

/// Serialize a network to `.fpgm` text.
pub fn to_string(net: &BayesianNetwork) -> String {
    let mut out = String::new();
    out.push_str("fpgm 1\n");
    out.push_str(&format!("name {}\n", net.name()));
    for v in net.variables() {
        out.push_str(&format!("var {} {}", v.name, v.cardinality));
        for s in &v.states {
            out.push(' ');
            out.push_str(s);
        }
        out.push('\n');
    }
    for v in 0..net.n_vars() {
        out.push_str(&format!("parents {}", v));
        for &p in net.parents(v) {
            out.push_str(&format!(" {p}"));
        }
        out.push('\n');
    }
    for v in 0..net.n_vars() {
        out.push_str(&format!("cpt {}", v));
        for p in &net.cpt(v).table {
            out.push_str(&format!(" {p:.17}"));
        }
        out.push('\n');
    }
    out.push_str("end\n");
    out
}

/// Parse `.fpgm` text into a network.
pub fn from_str(text: &str) -> Result<BayesianNetwork> {
    let mut lines = text.lines().map(str::trim).filter(|l| {
        !l.is_empty() && !l.starts_with('#')
    });
    let header = lines.next().context("empty fpgm file")?;
    if header != "fpgm 1" {
        bail!("unsupported fpgm header: {header:?}");
    }
    let mut name = String::from("unnamed");
    let mut variables: Vec<Variable> = Vec::new();
    let mut parents: Vec<Vec<usize>> = Vec::new();
    let mut cpts: Vec<Option<Vec<f64>>> = Vec::new();
    let mut saw_end = false;

    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("name") => {
                name = it.collect::<Vec<_>>().join(" ");
            }
            Some("var") => {
                let vname = it.next().context("var line missing name")?;
                let card: usize = it
                    .next()
                    .context("var line missing cardinality")?
                    .parse()
                    .context("bad cardinality")?;
                let states: Vec<String> = it.map(String::from).collect();
                if !states.is_empty() && states.len() != card {
                    bail!("var {vname}: {} state names for cardinality {card}", states.len());
                }
                let mut v = Variable::new(vname, card);
                v.states = states;
                variables.push(v);
                parents.push(Vec::new());
                cpts.push(None);
            }
            Some("parents") => {
                let v: usize = it.next().context("parents line missing index")?.parse()?;
                if v >= variables.len() {
                    bail!("parents line: variable index {v} out of range");
                }
                let ps: Vec<usize> = it
                    .map(|t| t.parse::<usize>().context("bad parent index"))
                    .collect::<Result<_>>()?;
                for &p in &ps {
                    if p >= variables.len() {
                        bail!("parent index {p} out of range");
                    }
                }
                parents[v] = ps;
            }
            Some("cpt") => {
                let v: usize = it.next().context("cpt line missing index")?.parse()?;
                if v >= variables.len() {
                    bail!("cpt line: variable index {v} out of range");
                }
                let vals: Vec<f64> = it
                    .map(|t| t.parse::<f64>().context("bad probability"))
                    .collect::<Result<_>>()?;
                cpts[v] = Some(vals);
            }
            Some("end") => {
                saw_end = true;
                break;
            }
            Some(other) => bail!("unknown fpgm directive: {other:?}"),
            None => unreachable!(),
        }
    }
    if !saw_end {
        bail!("fpgm file missing 'end'");
    }

    let n = variables.len();
    let mut dag = Dag::new(n);
    for (v, ps) in parents.iter().enumerate() {
        for &p in ps {
            dag.add_edge_unchecked(p, v);
        }
    }
    if dag.topological_order().is_none() {
        bail!("fpgm structure is cyclic");
    }
    let cpts: Vec<Cpt> = (0..n)
        .map(|v| {
            let table = cpts[v]
                .take()
                .with_context(|| format!("missing cpt for variable {v}"))?;
            let ps = dag.parents(v).to_vec();
            let pcards: Vec<usize> =
                ps.iter().map(|&p| variables[p].cardinality).collect();
            let expect: usize =
                pcards.iter().product::<usize>() * variables[v].cardinality;
            if table.len() != expect {
                bail!("cpt for variable {v}: expected {expect} entries, got {}", table.len());
            }
            Ok(Cpt::new(v, ps, pcards, variables[v].cardinality, table))
        })
        .collect::<Result<_>>()?;
    Ok(BayesianNetwork::new(name, variables, dag, cpts))
}

/// Write a network to a `.fpgm` file.
pub fn save(net: &BayesianNetwork, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_string(net))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load a network from a `.fpgm` file.
pub fn load(path: &std::path::Path) -> Result<BayesianNetwork> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_str(&text).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Evidence;
    use crate::network::repository;

    #[test]
    fn roundtrip_all_builtins() {
        for name in repository::BUILTIN_NAMES {
            let net = repository::by_name(name).unwrap();
            let text = to_string(&net);
            let back = from_str(&text).unwrap();
            assert_eq!(back.name(), net.name());
            assert_eq!(back.n_vars(), net.n_vars());
            assert_eq!(back.dag().edges(), net.dag().edges());
            for v in 0..net.n_vars() {
                assert_eq!(back.cpt(v).table, net.cpt(v).table, "{name} var {v}");
                assert_eq!(back.variable(v).states, net.variable(v).states);
            }
        }
    }

    #[test]
    fn roundtrip_preserves_inference() {
        let net = repository::asia();
        let back = from_str(&to_string(&net)).unwrap();
        let ev = Evidence::new().with(0, 1);
        for v in 0..net.n_vars() {
            let a = net.brute_force_posterior(v, &ev);
            let b = back.brute_force_posterior(v, &ev);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str("").is_err());
        assert!(from_str("fpgm 2\nend\n").is_err());
        assert!(from_str("fpgm 1\nvar x 2\nend\n").is_err()); // missing cpt
        assert!(from_str("fpgm 1\nbogus\nend\n").is_err());
        assert!(from_str("fpgm 1\nvar x 2\ncpt 0 0.5 0.5\n").is_err()); // no end
    }

    #[test]
    fn rejects_cycles() {
        let text = "fpgm 1\nname c\nvar a 2\nvar b 2\nparents 0 1\nparents 1 0\ncpt 0 0.5 0.5 0.5 0.5\ncpt 1 0.5 0.5 0.5 0.5\nend\n";
        assert!(from_str(text).is_err());
    }

    #[test]
    fn rejects_wrong_cpt_size() {
        let text = "fpgm 1\nvar a 2\nparents 0\ncpt 0 1.0\nend\n";
        assert!(from_str(text).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let net = repository::sprinkler();
        let mut text = String::from("# header comment\n\n");
        text.push_str(&to_string(&net));
        let back = from_str(&text).unwrap();
        assert_eq!(back.n_vars(), 4);
    }
}
