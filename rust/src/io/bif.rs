//! BIF (Bayesian Interchange Format) parser and writer — the format the
//! bnlearn repository distributes networks in. Together with
//! [`super::fpgm`] this provides the paper's "format transformation across
//! network representations" feature.
//!
//! Supported subset: `network`, `variable` blocks with
//! `type discrete [k] { s1, s2 ... }`, and `probability` blocks in both
//! root form (`table p1, p2;`) and conditional form
//! (`(s_p1, s_p2) p1, p2;` rows). This covers the repository networks.

use crate::core::Variable;
use crate::graph::Dag;
use crate::network::{BayesianNetwork, Cpt};
use anyhow::{bail, Context, Result};

/// Serialize a network to BIF text.
pub fn to_string(net: &BayesianNetwork) -> String {
    let mut out = String::new();
    out.push_str(&format!("network {} {{\n}}\n", sanitize(net.name())));
    for v in net.variables() {
        out.push_str(&format!(
            "variable {} {{\n  type discrete [ {} ] {{ ",
            sanitize(&v.name),
            v.cardinality
        ));
        let names: Vec<String> =
            (0..v.cardinality).map(|s| sanitize(&v.state_name(s))).collect();
        out.push_str(&names.join(", "));
        out.push_str(" };\n}\n");
    }
    for v in 0..net.n_vars() {
        let cpt = net.cpt(v);
        let vname = sanitize(&net.variable(v).name);
        if cpt.parents.is_empty() {
            let probs: Vec<String> =
                cpt.table.iter().map(|p| format!("{p}")).collect();
            out.push_str(&format!(
                "probability ( {vname} ) {{\n  table {};\n}}\n",
                probs.join(", ")
            ));
        } else {
            let pnames: Vec<String> = cpt
                .parents
                .iter()
                .map(|&p| sanitize(&net.variable(p).name))
                .collect();
            out.push_str(&format!(
                "probability ( {vname} | {} ) {{\n",
                pnames.join(", ")
            ));
            let mut digits = vec![0usize; cpt.parents.len()];
            for cfg in 0..cpt.n_parent_configs() {
                let states: Vec<String> = digits
                    .iter()
                    .enumerate()
                    .map(|(k, &d)| sanitize(&net.variable(cpt.parents[k]).state_name(d)))
                    .collect();
                let probs: Vec<String> =
                    cpt.row(cfg).iter().map(|p| format!("{p}")).collect();
                out.push_str(&format!(
                    "  ( {} ) {};\n",
                    states.join(", "),
                    probs.join(", ")
                ));
                // advance mixed radix, last fastest
                for k in (0..digits.len()).rev() {
                    digits[k] += 1;
                    if digits[k] < cpt.parent_cards[k] {
                        break;
                    }
                    digits[k] = 0;
                }
            }
            out.push_str("}\n");
        }
    }
    out
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_alphanumeric() || c == '_' || c == '-' { c } else { '_' })
        .collect()
}

/// Tokenizer: BIF is brace/paren/comma/semicolon punctuated.
fn tokenize(text: &str) -> Vec<String> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '/' if chars.peek() == Some(&'/') => {
                // line comment
                for c2 in chars.by_ref() {
                    if c2 == '\n' {
                        break;
                    }
                }
            }
            '{' | '}' | '(' | ')' | ',' | ';' | '|' | '[' | ']' => {
                if !cur.is_empty() {
                    tokens.push(std::mem::take(&mut cur));
                }
                tokens.push(c.to_string());
            }
            c if c.is_whitespace() => {
                if !cur.is_empty() {
                    tokens.push(std::mem::take(&mut cur));
                }
            }
            c => cur.push(c),
        }
    }
    if !cur.is_empty() {
        tokens.push(cur);
    }
    tokens
}

struct Parser {
    tokens: Vec<String>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&str> {
        self.tokens.get(self.pos).map(String::as_str)
    }

    fn next(&mut self) -> Result<&str> {
        let t = self.tokens.get(self.pos).context("unexpected end of BIF")?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, want: &str) -> Result<()> {
        let t = self.next()?;
        if t != want {
            bail!("expected {want:?}, found {t:?}");
        }
        Ok(())
    }

    fn skip_block(&mut self) -> Result<()> {
        self.expect("{")?;
        let mut depth = 1;
        while depth > 0 {
            match self.next()? {
                "{" => depth += 1,
                "}" => depth -= 1,
                _ => {}
            }
        }
        Ok(())
    }
}

/// Parse BIF text into a network.
pub fn from_str(text: &str) -> Result<BayesianNetwork> {
    let mut p = Parser { tokens: tokenize(text), pos: 0 };
    let mut name = String::from("bif");
    let mut variables: Vec<Variable> = Vec::new();
    // (child name, parent names, rows [(parent states, probs)])
    type ProbBlock = (String, Vec<String>, Vec<(Vec<String>, Vec<f64>)>);
    let mut prob_blocks: Vec<ProbBlock> = Vec::new();

    while let Some(tok) = p.peek() {
        match tok {
            "network" => {
                p.next()?;
                name = p.next()?.to_string();
                p.skip_block()?;
            }
            "variable" => {
                p.next()?;
                let vname = p.next()?.to_string();
                p.expect("{")?;
                let mut states: Vec<String> = Vec::new();
                while p.peek() != Some("}") {
                    if p.peek() == Some("type") {
                        p.next()?; // type
                        p.expect("discrete")?;
                        p.expect("[")?;
                        let _card: usize = p.next()?.parse()?;
                        p.expect("]")?;
                        p.expect("{")?;
                        loop {
                            let t = p.next()?;
                            match t {
                                "}" => break,
                                "," => {}
                                s => states.push(s.to_string()),
                            }
                        }
                        p.expect(";")?;
                    } else {
                        // skip unknown property up to ';'
                        while p.next()? != ";" {}
                    }
                }
                p.expect("}")?;
                if states.is_empty() {
                    bail!("variable {vname} has no states");
                }
                variables.push(Variable::with_states(vname, states));
            }
            "probability" => {
                p.next()?;
                p.expect("(")?;
                let child = p.next()?.to_string();
                let mut parents: Vec<String> = Vec::new();
                if p.peek() == Some("|") {
                    p.next()?;
                    loop {
                        match p.next()? {
                            ")" => break,
                            "," => {}
                            s => parents.push(s.to_string()),
                        }
                    }
                } else {
                    p.expect(")")?;
                }
                p.expect("{")?;
                let mut rows: Vec<(Vec<String>, Vec<f64>)> = Vec::new();
                while p.peek() != Some("}") {
                    match p.peek() {
                        Some("table") => {
                            p.next()?;
                            let mut probs = Vec::new();
                            loop {
                                match p.next()? {
                                    ";" => break,
                                    "," => {}
                                    t => probs.push(t.parse::<f64>()?),
                                }
                            }
                            rows.push((Vec::new(), probs));
                        }
                        Some("(") => {
                            p.next()?;
                            let mut states = Vec::new();
                            loop {
                                match p.next()? {
                                    ")" => break,
                                    "," => {}
                                    s => states.push(s.to_string()),
                                }
                            }
                            let mut probs = Vec::new();
                            loop {
                                match p.next()? {
                                    ";" => break,
                                    "," => {}
                                    t => probs.push(t.parse::<f64>()?),
                                }
                            }
                            rows.push((states, probs));
                        }
                        other => bail!("unexpected token in probability block: {other:?}"),
                    }
                }
                p.expect("}")?;
                prob_blocks.push((child, parents, rows));
            }
            other => bail!("unexpected top-level token: {other:?}"),
        }
    }

    // Assemble. Parent order in BIF may differ from sorted-VarId order;
    // rows are re-indexed into the canonical layout.
    let var_index = |n: &str| -> Result<usize> {
        variables
            .iter()
            .position(|v| v.name == n)
            .with_context(|| format!("unknown variable {n}"))
    };
    let n = variables.len();
    let mut dag = Dag::new(n);
    let mut cpt_slots: Vec<Option<Cpt>> = vec![None; n];
    for (child, parents, rows) in &prob_blocks {
        let c = var_index(child)?;
        let bif_parents: Vec<usize> =
            parents.iter().map(|s| var_index(s)).collect::<Result<_>>()?;
        for &pp in &bif_parents {
            dag.add_edge_unchecked(pp, c);
        }
        let mut sorted_parents = bif_parents.clone();
        sorted_parents.sort_unstable();
        let parent_cards: Vec<usize> = sorted_parents
            .iter()
            .map(|&pp| variables[pp].cardinality)
            .collect();
        let card = variables[c].cardinality;
        let n_cfg: usize = parent_cards.iter().product();
        let mut table = vec![f64::NAN; n_cfg * card];
        for (states, probs) in rows {
            if probs.len() != card {
                bail!("probability row for {child} has {} entries, expected {card}", probs.len());
            }
            let cfg = if states.is_empty() {
                0
            } else {
                if states.len() != bif_parents.len() {
                    bail!("row state count mismatch for {child}");
                }
                // Map BIF parent order -> canonical sorted order.
                let mut cfg = 0usize;
                for &sp in &sorted_parents {
                    let k = bif_parents.iter().position(|&q| q == sp).unwrap();
                    let st = variables[sp].state_index(&states[k]).with_context(|| {
                        format!("bad state {:?} for {}", states[k], variables[sp].name)
                    })?;
                    cfg = cfg * variables[sp].cardinality + st;
                }
                cfg
            };
            for (s, &pv) in probs.iter().enumerate() {
                table[cfg * card + s] = pv;
            }
        }
        if table.iter().any(|x| x.is_nan()) {
            bail!("probability table for {child} has unspecified rows");
        }
        cpt_slots[c] = Some(Cpt::new(c, sorted_parents, parent_cards, card, table));
    }
    if dag.topological_order().is_none() {
        bail!("BIF structure is cyclic");
    }
    let cpts: Vec<Cpt> = cpt_slots
        .into_iter()
        .enumerate()
        .map(|(v, c)| c.with_context(|| format!("missing probability block for variable {v}")))
        .collect::<Result<_>>()?;
    Ok(BayesianNetwork::new(name, variables, dag, cpts))
}

/// Load a `.bif` file.
pub fn load(path: &std::path::Path) -> Result<BayesianNetwork> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_str(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Save a `.bif` file.
pub fn save(net: &BayesianNetwork, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_string(net))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;

    #[test]
    fn roundtrip_builtins() {
        for name in repository::BUILTIN_NAMES {
            let net = repository::by_name(name).unwrap();
            let text = to_string(&net);
            let back = from_str(&text).unwrap_or_else(|e| panic!("{name}: {e:?}"));
            assert_eq!(back.n_vars(), net.n_vars());
            assert_eq!(back.dag().edges(), net.dag().edges(), "{name}");
            for v in 0..net.n_vars() {
                for (a, b) in back.cpt(v).table.iter().zip(&net.cpt(v).table) {
                    assert!((a - b).abs() < 1e-12, "{name} var {v}");
                }
            }
        }
    }

    #[test]
    fn parses_handwritten_bif() {
        let text = r#"
network test {
}
variable rain {
  type discrete [ 2 ] { no, yes };
}
variable grass {
  type discrete [ 2 ] { dry, wet };
}
probability ( rain ) {
  table 0.8, 0.2;
}
probability ( grass | rain ) {
  ( no ) 0.9, 0.1;
  ( yes ) 0.2, 0.8;
}
"#;
        let net = from_str(text).unwrap();
        assert_eq!(net.n_vars(), 2);
        let rain = net.var_index("rain").unwrap();
        let grass = net.var_index("grass").unwrap();
        assert!(net.dag().has_edge(rain, grass));
        assert!((net.cpt(grass).prob(1, 1) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn parser_handles_comments() {
        let text = "network t {\n}\n// comment line\nvariable x {\n type discrete [ 2 ] { a, b };\n}\nprobability ( x ) {\n table 0.5, 0.5;\n}\n";
        assert!(from_str(text).is_ok());
    }

    #[test]
    fn rejects_missing_probability() {
        let text = "network t {\n}\nvariable x {\n type discrete [ 2 ] { a, b };\n}\n";
        assert!(from_str(text).is_err());
    }

    #[test]
    fn bif_to_fpgm_transform() {
        // The format-transformation path: BIF -> network -> fpgm -> network.
        let net = repository::asia();
        let bif = to_string(&net);
        let via_bif = from_str(&bif).unwrap();
        let fpgm_text = crate::io::fpgm::to_string(&via_bif);
        let back = crate::io::fpgm::from_str(&fpgm_text).unwrap();
        assert_eq!(back.dag().edges(), net.dag().edges());
    }
}
