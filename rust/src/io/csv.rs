//! CSV dataset loading/saving (header row = variable names; values are
//! state names or indices).
//!
//! Ingestion is either **strict** (any malformed row fails the whole
//! load — the historical behaviour, still the [`from_str`] default) or
//! **permissive** ([`IngestOptions::permissive`]): malformed rows —
//! ragged field counts, states a fixed schema does not know — are
//! *quarantined* into a bounded, reported reject set and the learn
//! proceeds on the surviving rows. The accounting invariant
//! `rows_kept + rows_quarantined == rows_total` holds in every mode and
//! is property-tested. A load where *every* row is quarantined still
//! errors: zero usable rows can never silently produce an empty learn.
//!
//! The `corrupt_row` fault site lives here: an armed chaos plan can
//! deterministically mangle rows before parsing, driving the quarantine
//! machinery through the same seeded harness as the wire faults.

use crate::core::{Dataset, Variable};
use crate::faults::{FaultAction, FaultHook, FaultSite};
use anyhow::{bail, Context, Result};

/// Datasets store states as `u8`, so ingestion refuses wider columns.
pub const MAX_STATES: usize = 256;

/// How ingestion treats malformed rows.
#[derive(Clone, Copy, Debug)]
pub struct IngestOptions {
    /// `false` (strict): first malformed row fails the load.
    /// `true`: malformed rows are quarantined and reported.
    pub permissive: bool,
    /// Cap on quarantine *examples* kept for the report (counts are
    /// always exact; only the per-row detail list is bounded).
    pub max_examples: usize,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions { permissive: false, max_examples: 16 }
    }
}

impl IngestOptions {
    pub fn strict() -> Self {
        Self::default()
    }

    pub fn permissive() -> Self {
        IngestOptions { permissive: true, ..Self::default() }
    }
}

/// What ingestion did: exact row accounting plus a bounded sample of
/// quarantined rows for diagnostics.
#[derive(Clone, Debug, Default)]
pub struct IngestReport {
    pub rows_total: usize,
    pub rows_kept: usize,
    pub rows_quarantined: usize,
    /// Up to [`IngestOptions::max_examples`] of `(line number, reason)`.
    pub examples: Vec<(usize, String)>,
    /// More rows were quarantined than `examples` records.
    pub examples_truncated: bool,
    /// Rows mangled by the `corrupt_row` fault site (chaos runs).
    pub corrupt_row_faults: u64,
}

impl IngestReport {
    fn quarantine(&mut self, max_examples: usize, line: usize, reason: String) {
        self.rows_quarantined += 1;
        if self.examples.len() < max_examples {
            self.examples.push((line, reason));
        } else {
            self.examples_truncated = true;
        }
    }

    /// One-line rendering for logs and CI greps.
    pub fn summary(&self) -> String {
        format!(
            "rows={} kept={} quarantined={} corrupt_row_faults={}",
            self.rows_total, self.rows_kept, self.rows_quarantined,
            self.corrupt_row_faults
        )
    }
}

/// Serialize a dataset to CSV with state names where available.
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    let names: Vec<&str> =
        ds.variables().iter().map(|v| v.name.as_str()).collect();
    out.push_str(&names.join(","));
    out.push('\n');
    for r in 0..ds.n_rows() {
        let row: Vec<String> = (0..ds.n_vars())
            .map(|v| ds.variable(v).state_name(ds.value(r, v)))
            .collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Parse a CSV into a dataset, strict mode (back-compat surface). State
/// spaces are inferred from the values seen (sorted for determinism)
/// unless `schema` provides variables.
pub fn from_str(text: &str, schema: Option<Vec<Variable>>) -> Result<Dataset> {
    ingest(text, schema, IngestOptions::strict(), &None).map(|(ds, _)| ds)
}

/// Full ingestion: strict or permissive, with exact quarantine
/// accounting and the `corrupt_row` fault site applied per data row.
pub fn ingest(
    text: &str,
    schema: Option<Vec<Variable>>,
    opts: IngestOptions,
    faults: &FaultHook,
) -> Result<(Dataset, IngestReport)> {
    let mut report = IngestReport::default();
    let mut lines = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty());
    let (_, header) = lines.next().context("empty CSV")?;
    let names: Vec<String> =
        header.split(',').map(|t| t.trim().to_string()).collect();
    let n = names.len();

    // Split rows up front, applying the corrupt_row fault site. Each kept
    // entry is `(line number, fields)`; `None` marks a quarantined row.
    let mut rows: Vec<Option<(usize, Vec<String>)>> = Vec::new();
    for (idx, line) in lines {
        let lineno = idx + 1;
        report.rows_total += 1;
        let mut owned = line.to_string();
        if let Some(f) = faults {
            if f.decide(FaultSite::CorruptRow, None) == FaultAction::Corrupt {
                // Deterministic mangling: an extra field makes the row
                // ragged, which the classifier below must quarantine.
                owned.push_str(",\u{0}corrupt");
                report.corrupt_row_faults += 1;
            }
        }
        let fields: Vec<String> =
            owned.split(',').map(|t| t.trim().to_string()).collect();
        if fields.len() != n {
            let reason =
                format!("{} fields, expected {n}", fields.len());
            if !opts.permissive {
                bail!("row at line {lineno}: {reason}");
            }
            report.quarantine(opts.max_examples, lineno, reason);
            rows.push(None);
        } else {
            rows.push(Some((lineno, fields)));
        }
    }

    let variables: Vec<Variable> = match schema {
        Some(vs) => {
            if vs.len() != n {
                bail!("schema has {} variables, CSV has {n}", vs.len());
            }
            vs
        }
        None => (0..n)
            .map(|c| {
                let mut states: Vec<String> = rows
                    .iter()
                    .flatten()
                    .map(|(_, r)| r[c].clone())
                    .collect();
                states.sort();
                states.dedup();
                if states.is_empty() {
                    // No surviving rows; give the column one placeholder
                    // state — the zero-usable-rows check below fires.
                    states.push("_".to_string());
                }
                Variable::with_states(names[c].clone(), states)
            })
            .collect(),
    };
    for v in &variables {
        if v.cardinality > MAX_STATES {
            bail!(
                "column {} has {} states (max {MAX_STATES})",
                v.name,
                v.cardinality
            );
        }
    }

    let mut ds = Dataset::new(variables);
    let mut buf = vec![0u8; n];
    'rows: for entry in rows.iter().flatten() {
        let (lineno, fields) = entry;
        for (c, tok) in fields.iter().enumerate() {
            match ds.variable(c).state_index(tok) {
                Some(s) => buf[c] = s as u8,
                None => {
                    let reason =
                        format!("unknown state {tok:?} for {}", names[c]);
                    if !opts.permissive {
                        bail!("row at line {lineno}: {reason}");
                    }
                    report.quarantine(opts.max_examples, *lineno, reason);
                    continue 'rows;
                }
            }
        }
        ds.push_row(&buf);
        report.rows_kept += 1;
    }

    debug_assert_eq!(
        report.rows_kept + report.rows_quarantined,
        report.rows_total
    );
    if report.rows_kept == 0 {
        bail!(
            "zero usable rows ({} quarantined of {})",
            report.rows_quarantined,
            report.rows_total
        );
    }
    Ok((ds, report))
}

pub fn load(path: &std::path::Path, schema: Option<Vec<Variable>>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_str(&text, schema)
}

/// Load with full ingestion control (permissive quarantine, faults).
pub fn load_ingest(
    path: &std::path::Path,
    schema: Option<Vec<Variable>>,
    opts: IngestOptions,
    faults: &FaultHook,
) -> Result<(Dataset, IngestReport)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    ingest(&text, schema, opts, faults)
}

pub fn save(ds: &Dataset, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_string(ds))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultPlan;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    #[test]
    fn roundtrip_with_schema() {
        let net = repository::asia();
        let mut rng = Pcg::seed_from(1);
        let ds = forward_sample_dataset(&net, 500, &mut rng);
        let text = to_string(&ds);
        let back = from_str(&text, Some(net.variables().to_vec())).unwrap();
        assert_eq!(back.n_rows(), ds.n_rows());
        for v in 0..ds.n_vars() {
            assert_eq!(back.column(v), ds.column(v));
        }
    }

    #[test]
    fn infers_states_deterministically() {
        let text = "a,b\nyes,1\nno,0\nyes,2\n";
        let ds = from_str(text, None).unwrap();
        // States sorted: a: [no, yes], b: [0, 1, 2]
        assert_eq!(ds.variable(0).states, vec!["no", "yes"]);
        assert_eq!(ds.cardinality(1), 3);
        assert_eq!(ds.column(0), &[1, 0, 1]);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(from_str("a,b\n1\n", None).is_err());
    }

    #[test]
    fn rejects_unknown_state_with_schema() {
        let schema = vec![Variable::with_states("a", ["x", "y"])];
        assert!(from_str("a\nz\n", Some(schema)).is_err());
    }

    #[test]
    fn permissive_quarantines_and_accounts() {
        let text = "a,b\nyes,1\nno\nyes,2,extra\nno,1\n";
        let (ds, report) =
            ingest(text, None, IngestOptions::permissive(), &None).unwrap();
        assert_eq!(report.rows_total, 4);
        assert_eq!(report.rows_kept, 2);
        assert_eq!(report.rows_quarantined, 2);
        assert_eq!(report.rows_kept + report.rows_quarantined, report.rows_total);
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(report.examples.len(), 2);
        assert!(report.summary().contains("quarantined=2"));
    }

    #[test]
    fn permissive_quarantines_unknown_states() {
        let schema = vec![Variable::with_states("a", ["x", "y"])];
        let (ds, report) = ingest(
            "a\nx\nz\ny\n",
            Some(schema),
            IngestOptions::permissive(),
            &None,
        )
        .unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(report.rows_quarantined, 1);
        assert_eq!(report.examples[0].1, "unknown state \"z\" for a");
    }

    #[test]
    fn zero_usable_rows_errors_even_permissive() {
        let err = ingest("a,b\nonly\n", None, IngestOptions::permissive(), &None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("zero usable rows"));
    }

    #[test]
    fn example_list_is_bounded() {
        let mut text = String::from("a,b\nok,1\n");
        for _ in 0..100 {
            text.push_str("bad\n");
        }
        let opts = IngestOptions { permissive: true, max_examples: 4 };
        let (_, report) = ingest(&text, None, opts, &None).unwrap();
        assert_eq!(report.rows_quarantined, 100);
        assert_eq!(report.examples.len(), 4);
        assert!(report.examples_truncated);
    }

    #[test]
    fn corrupt_row_fault_drives_quarantine() {
        let net = repository::asia();
        let mut rng = Pcg::seed_from(2);
        let ds = forward_sample_dataset(&net, 200, &mut rng);
        let text = to_string(&ds);
        let plan = FaultPlan::parse("seed=42,corrupt=0.25@corrupt_row").unwrap();
        let run = |faults: &FaultHook| {
            ingest(&text, None, IngestOptions::permissive(), faults).unwrap().1
        };
        let a = run(&Some(plan.arm(None)));
        let b = run(&Some(plan.arm(None)));
        // Deterministic: same plan, same quarantine accounting.
        assert_eq!(a.rows_quarantined, b.rows_quarantined);
        assert_eq!(a.corrupt_row_faults, b.corrupt_row_faults);
        assert!(a.corrupt_row_faults > 20, "{}", a.corrupt_row_faults);
        assert_eq!(a.rows_quarantined, a.corrupt_row_faults as usize);
        assert_eq!(a.rows_kept + a.rows_quarantined, a.rows_total);
        // Disarmed: nothing quarantined.
        let clean = run(&None);
        assert_eq!(clean.rows_quarantined, 0);
        assert_eq!(clean.rows_kept, 200);
    }
}
