//! CSV dataset loading/saving (header row = variable names; values are
//! state names or indices).

use crate::core::{Dataset, Variable};
use anyhow::{bail, Context, Result};

/// Serialize a dataset to CSV with state names where available.
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    let names: Vec<&str> =
        ds.variables().iter().map(|v| v.name.as_str()).collect();
    out.push_str(&names.join(","));
    out.push('\n');
    for r in 0..ds.n_rows() {
        let row: Vec<String> = (0..ds.n_vars())
            .map(|v| ds.variable(v).state_name(ds.value(r, v)))
            .collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Parse a CSV into a dataset. State spaces are inferred from the values
/// seen (sorted for determinism) unless `schema` provides variables.
pub fn from_str(text: &str, schema: Option<Vec<Variable>>) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().context("empty CSV")?;
    let names: Vec<&str> = header.split(',').map(str::trim).collect();
    let n = names.len();
    let rows: Vec<Vec<&str>> = lines
        .map(|l| l.split(',').map(str::trim).collect::<Vec<_>>())
        .collect();
    for (i, r) in rows.iter().enumerate() {
        if r.len() != n {
            bail!("row {} has {} fields, expected {n}", i + 2, r.len());
        }
    }
    let variables: Vec<Variable> = match schema {
        Some(vs) => {
            if vs.len() != n {
                bail!("schema has {} variables, CSV has {n}", vs.len());
            }
            vs
        }
        None => (0..n)
            .map(|c| {
                let mut states: Vec<String> =
                    rows.iter().map(|r| r[c].to_string()).collect();
                states.sort();
                states.dedup();
                Variable::with_states(names[c], states)
            })
            .collect(),
    };
    let mut ds = Dataset::new(variables);
    let mut buf = vec![0u8; n];
    for (i, r) in rows.iter().enumerate() {
        for (c, tok) in r.iter().enumerate() {
            let s = ds
                .variable(c)
                .state_index(tok)
                .with_context(|| format!("row {}: unknown state {tok:?} for {}", i + 2, names[c]))?;
            buf[c] = s as u8;
        }
        ds.push_row(&buf);
    }
    Ok(ds)
}

pub fn load(path: &std::path::Path, schema: Option<Vec<Variable>>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_str(&text, schema)
}

pub fn save(ds: &Dataset, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_string(ds))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    #[test]
    fn roundtrip_with_schema() {
        let net = repository::asia();
        let mut rng = Pcg::seed_from(1);
        let ds = forward_sample_dataset(&net, 500, &mut rng);
        let text = to_string(&ds);
        let back = from_str(&text, Some(net.variables().to_vec())).unwrap();
        assert_eq!(back.n_rows(), ds.n_rows());
        for v in 0..ds.n_vars() {
            assert_eq!(back.column(v), ds.column(v));
        }
    }

    #[test]
    fn infers_states_deterministically() {
        let text = "a,b\nyes,1\nno,0\nyes,2\n";
        let ds = from_str(text, None).unwrap();
        // States sorted: a: [no, yes], b: [0, 1, 2]
        assert_eq!(ds.variable(0).states, vec!["no", "yes"]);
        assert_eq!(ds.cardinality(1), 3);
        assert_eq!(ds.column(0), &[1, 0, 1]);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(from_str("a,b\n1\n", None).is_err());
    }

    #[test]
    fn rejects_unknown_state_with_schema() {
        let schema = vec![Variable::with_states("a", ["x", "y"])];
        assert!(from_str("a\nz\n", Some(schema)).is_err());
    }
}
