//! Serialization and format transformation (paper §2, auxiliary
//! features): the native `.fpgm` text format (shared with the Python
//! compile path — both sides of the AOT bridge parse it), the standard
//! BIF format, and CSV datasets.

pub mod bif;
pub mod csv;
pub mod fpgm;
