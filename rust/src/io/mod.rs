//! Serialization and format transformation (paper §2, auxiliary
//! features): the native `.fpgm` text format (shared with the Python
//! compile path — both sides of the AOT bridge parse it), the standard
//! BIF format, and CSV datasets.
//!
//! All load paths are **total**: untrusted bytes go through
//! [`model::validate_raw`] before any constructor that asserts, so a
//! corrupted or truncated file is a typed [`model::ModelError`] — never
//! a panic. Snapshots written by [`fpgm::save_atomic`] carry a CRC32
//! trailer and land via temp-file + fsync + rename, so a crash mid-write
//! leaves either the old snapshot or a detectable partial, never a
//! silently half-written model.

pub mod bif;
pub mod csv;
pub mod fpgm;
pub mod model;
