//! Model validation: the gate between untrusted bytes and a live
//! [`BayesianNetwork`].
//!
//! The constructors in `network/` enforce their invariants with
//! `assert!` — correct for programmer errors, fatal for file input: a
//! zero-cardinality `var` line or a self-loop parent in a corrupted
//! `.fpgm` file would panic the process before any error could be
//! reported. This module provides the *total* path: parse into a
//! [`RawNet`], [`validate_raw`] it (every construction precondition
//! plus probability sanity), then [`build`] — which can no longer trip
//! an assert. Freshly *learned* models pass the same bar via
//! [`validate_network`] before the router will register them.
//!
//! Errors are typed ([`ModelError`]): `Truncated` (the bytes stop
//! early — a torn write), `Corrupt` (structure or checksum damage),
//! `Invalid` (well-formed bytes describing a bad model), `Io`. Callers
//! branch on the variant to pick a recovery (e.g. fall back to the
//! last-good snapshot) instead of string-matching messages.

use std::fmt;

use crate::core::Variable;
use crate::graph::Dag;
use crate::network::{BayesianNetwork, Cpt};

/// Per-row CPT sum tolerance (matches `Cpt::validate`).
pub const ROW_SUM_TOLERANCE: f64 = 1e-6;
/// Upper bound on a single entry (matches `Cpt::validate`).
pub const ENTRY_SLACK: f64 = 1e-9;
/// Cardinality bound — far above any discrete BN in the repository, low
/// enough that a corrupted count cannot drive a pathological allocation.
pub const MAX_CARDINALITY: usize = 1 << 16;
/// Arity (parent-count) bound per variable.
pub const MAX_PARENTS: usize = 32;
/// Bound on one CPT's entry count (size checks use checked arithmetic,
/// so an overflowing product is caught, not wrapped).
pub const MAX_TABLE_ENTRIES: usize = 1 << 26;

/// Typed failure of loading or validating a model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelError {
    /// The input stops before the format says it should (torn write).
    Truncated(String),
    /// The input is structurally damaged or fails its checksum.
    Corrupt(String),
    /// Well-formed input describing an invalid model (bad probabilities,
    /// cycles, out-of-bounds cardinality/arity).
    Invalid(String),
    /// The underlying read/write failed.
    Io(String),
}

impl fmt::Display for ModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelError::Truncated(d) => write!(f, "model truncated: {d}"),
            ModelError::Corrupt(d) => write!(f, "model corrupt: {d}"),
            ModelError::Invalid(d) => write!(f, "model invalid: {d}"),
            ModelError::Io(d) => write!(f, "model io error: {d}"),
        }
    }
}

impl std::error::Error for ModelError {}

/// A parsed-but-unvalidated network: exactly what the bytes said, no
/// invariants assumed. `variables[i]` is `(name, cardinality, states)`;
/// `parents[i]`/`tables[i]` align with it.
#[derive(Clone, Debug, Default)]
pub struct RawNet {
    pub name: String,
    pub variables: Vec<(String, usize, Vec<String>)>,
    pub parents: Vec<Vec<usize>>,
    pub tables: Vec<Vec<f64>>,
}

/// What a validation pass measured (also the registration-gate report).
#[derive(Clone, Copy, Debug, Default)]
pub struct ValidationReport {
    pub n_vars: usize,
    pub n_entries: usize,
    /// Worst |row sum - 1| seen across all CPT rows.
    pub max_row_err: f64,
}

/// Check every construction precondition and probability invariant on a
/// raw net. After `validate_raw(raw)?`, [`build`] cannot panic.
pub fn validate_raw(raw: &RawNet) -> Result<ValidationReport, ModelError> {
    let n = raw.variables.len();
    let invalid = |d: String| Err(ModelError::Invalid(d));
    if n == 0 {
        return invalid("no variables".into());
    }
    if raw.parents.len() != n || raw.tables.len() != n {
        return Err(ModelError::Corrupt(format!(
            "{} parent lists / {} tables for {n} variables",
            raw.parents.len(),
            raw.tables.len()
        )));
    }
    for (i, (name, card, states)) in raw.variables.iter().enumerate() {
        if *card == 0 || *card > MAX_CARDINALITY {
            return invalid(format!(
                "variable {name:?} cardinality {card} outside 1..={MAX_CARDINALITY}"
            ));
        }
        if !states.is_empty() && states.len() != *card {
            return invalid(format!(
                "variable {name:?}: {} state names for cardinality {card}",
                states.len()
            ));
        }
        if raw.variables[..i].iter().any(|(other, _, _)| other == name) {
            return invalid(format!("duplicate variable name {name:?}"));
        }
    }
    for (v, ps) in raw.parents.iter().enumerate() {
        if ps.len() > MAX_PARENTS {
            return invalid(format!(
                "variable {v} has {} parents (max {MAX_PARENTS})",
                ps.len()
            ));
        }
        for &p in ps {
            if p >= n {
                return invalid(format!("variable {v}: parent index {p} out of range"));
            }
            if p == v {
                return invalid(format!("variable {v} is its own parent"));
            }
        }
        let mut sorted = ps.clone();
        sorted.sort_unstable();
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            return invalid(format!("variable {v} has duplicate parents"));
        }
    }
    // Acyclicity (Kahn) over the parent lists, before any Dag is built.
    let mut indeg: Vec<usize> = raw.parents.iter().map(Vec::len).collect();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (v, ps) in raw.parents.iter().enumerate() {
        for &p in ps {
            children[p].push(v);
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut seen = 0usize;
    while let Some(v) = queue.pop() {
        seen += 1;
        for &c in &children[v] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                queue.push(c);
            }
        }
    }
    if seen != n {
        return invalid("structure is cyclic".into());
    }
    // Table shapes (checked arithmetic) and probability sanity.
    let mut report = ValidationReport { n_vars: n, ..Default::default() };
    for (v, table) in raw.tables.iter().enumerate() {
        let card = raw.variables[v].1;
        let mut expect = card;
        for &p in &raw.parents[v] {
            expect = match expect.checked_mul(raw.variables[p].1) {
                Some(e) if e <= MAX_TABLE_ENTRIES => e,
                _ => {
                    return invalid(format!(
                        "variable {v}: CPT size overflows {MAX_TABLE_ENTRIES}"
                    ))
                }
            };
        }
        if table.len() != expect {
            return invalid(format!(
                "variable {v}: expected {expect} CPT entries, got {}",
                table.len()
            ));
        }
        report.n_entries += table.len();
        for row in table.chunks(card) {
            let mut sum = 0.0;
            for &p in row {
                if !p.is_finite() || !(0.0..=1.0 + ENTRY_SLACK).contains(&p) {
                    return invalid(format!(
                        "variable {v}: CPT entry {p} is not a probability"
                    ));
                }
                sum += p;
            }
            let err = (sum - 1.0).abs();
            if err > ROW_SUM_TOLERANCE {
                return invalid(format!("variable {v}: CPT row sums to {sum}"));
            }
            report.max_row_err = report.max_row_err.max(err);
        }
    }
    Ok(report)
}

/// Assemble a validated [`RawNet`] into a live network. Validates first;
/// after that the constructor asserts are unreachable.
pub fn build(raw: RawNet) -> Result<BayesianNetwork, ModelError> {
    validate_raw(&raw)?;
    let n = raw.variables.len();
    let variables: Vec<Variable> = raw
        .variables
        .into_iter()
        .map(|(name, card, states)| {
            let mut v = Variable::new(name, card);
            v.states = states;
            v
        })
        .collect();
    let mut dag = Dag::new(n);
    for (v, ps) in raw.parents.iter().enumerate() {
        for &p in ps {
            dag.add_edge_unchecked(p, v);
        }
    }
    let cpts: Vec<Cpt> = raw
        .tables
        .into_iter()
        .enumerate()
        .map(|(v, table)| {
            let ps = dag.parents(v).to_vec();
            let pcards: Vec<usize> =
                ps.iter().map(|&p| variables[p].cardinality).collect();
            Cpt::new(v, ps, pcards, variables[v].cardinality, table)
        })
        .collect();
    Ok(BayesianNetwork::new(raw.name, variables, dag, cpts))
}

/// Validate an already-constructed network — the registration gate every
/// freshly learned model passes before the router will serve it. The
/// constructors guarantee most invariants; this re-checks the numeric
/// ones (a degenerate learn could in principle emit NaN) and reports
/// what it measured.
pub fn validate_network(net: &BayesianNetwork) -> Result<ValidationReport, ModelError> {
    let mut report =
        ValidationReport { n_vars: net.n_vars(), ..Default::default() };
    for v in 0..net.n_vars() {
        let cpt = net.cpt(v);
        report.n_entries += cpt.table.len();
        if net.cardinality(v) > MAX_CARDINALITY {
            return Err(ModelError::Invalid(format!(
                "variable {v} cardinality {} outside bounds",
                net.cardinality(v)
            )));
        }
        for cfg in 0..cpt.n_parent_configs() {
            let row = cpt.row(cfg);
            let mut sum = 0.0;
            for &p in row {
                if !p.is_finite() || !(0.0..=1.0 + ENTRY_SLACK).contains(&p) {
                    return Err(ModelError::Invalid(format!(
                        "variable {v}: CPT entry {p} is not a probability"
                    )));
                }
                sum += p;
            }
            let err = (sum - 1.0).abs();
            if err > ROW_SUM_TOLERANCE {
                return Err(ModelError::Invalid(format!(
                    "variable {v}: CPT row {cfg} sums to {sum}"
                )));
            }
            report.max_row_err = report.max_row_err.max(err);
        }
    }
    Ok(report)
}

/// CRC32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) — the snapshot
/// trailer digest. Bitwise (no table): snapshots are small and this
/// keeps the implementation obviously correct and dependency-free.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;

    fn raw_two_node() -> RawNet {
        RawNet {
            name: "two".into(),
            variables: vec![
                ("a".into(), 2, vec![]),
                ("b".into(), 2, vec![]),
            ],
            parents: vec![vec![], vec![0]],
            tables: vec![vec![0.7, 0.3], vec![0.9, 0.1, 0.2, 0.8]],
        }
    }

    #[test]
    fn valid_raw_builds() {
        let report = validate_raw(&raw_two_node()).unwrap();
        assert_eq!(report.n_vars, 2);
        assert_eq!(report.n_entries, 6);
        assert!(report.max_row_err < 1e-12);
        let net = build(raw_two_node()).unwrap();
        assert_eq!(net.n_vars(), 2);
        assert_eq!(net.parents(1), &[0]);
    }

    #[test]
    fn rejects_every_construction_panic_path() {
        let cases: Vec<(&str, Box<dyn Fn(&mut RawNet)>)> = vec![
            ("zero cardinality", Box::new(|r| r.variables[0].1 = 0)),
            ("huge cardinality", Box::new(|r| r.variables[0].1 = MAX_CARDINALITY + 1)),
            ("self parent", Box::new(|r| r.parents[1] = vec![1])),
            ("dup parent", Box::new(|r| r.parents[1] = vec![0, 0])),
            ("parent oob", Box::new(|r| r.parents[1] = vec![7])),
            ("cycle", Box::new(|r| r.parents[0] = vec![1])),
            ("wrong table size", Box::new(|r| {
                r.tables[1].pop();
            })),
            ("nan entry", Box::new(|r| r.tables[0][0] = f64::NAN)),
            ("inf entry", Box::new(|r| r.tables[0][0] = f64::INFINITY)),
            ("negative entry", Box::new(|r| r.tables[0][0] = -0.1)),
            ("bad row sum", Box::new(|r| r.tables[0] = vec![0.9, 0.9])),
            ("dup name", Box::new(|r| r.variables[1].0 = "a".into())),
            ("bad state count", Box::new(|r| r.variables[0].2 = vec!["x".into()])),
        ];
        for (label, mutate) in cases {
            let mut raw = raw_two_node();
            mutate(&mut raw);
            assert!(build(raw).is_err(), "{label} accepted");
        }
    }

    #[test]
    fn validate_network_passes_builtins() {
        for name in repository::BUILTIN_NAMES {
            let net = repository::by_name(name).unwrap();
            let report = validate_network(&net).unwrap();
            assert_eq!(report.n_vars, net.n_vars());
            assert!(report.max_row_err <= ROW_SUM_TOLERANCE, "{name}");
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard check value for "123456789" under CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }
}
