//! Shared sufficient-statistics substrate: grouped contingency counting
//! over the column-major [`Dataset`] plus a thread-safe, sharded count
//! cache with subset projection.
//!
//! Every learning-side consumer — conditional-independence tests
//! ([`crate::structure::CiTester`]), decomposable structure scores
//! ([`crate::structure::Scorer`]), maximum-likelihood parameter
//! estimation ([`crate::parameter`]) and the classifier
//! ([`crate::classify`]) — needs the same primitive: integer counts
//! `n(V)` over a small set of variables `V`. Before this module each of
//! them re-counted raw rows independently; now they all route through
//!
//! * [`ContingencyTable`] — one streaming column-major pass builds the
//!   joint count table over a *sorted* variable set (the paper's
//!   optimization (ii): the scan touches `|V|` dense arrays
//!   sequentially). Marginals, permuted layouts and subset tables are
//!   derived from the joint by table-sized passes instead of re-reading
//!   rows (optimization (iii), computation grouping).
//! * [`CountCache`] — a sharded, read-mostly map from sorted variable
//!   sets to `Arc<ContingencyTable>`. Hits skip the `O(n_rows)` scan
//!   entirely; misses first try **subset projection** — deriving the
//!   requested table from a cached *superset* table by marginalizing
//!   counts out (`O(superset cells)`, exact integer sums) — and only
//!   scan rows when no affordable superset is cached. Projection is the
//!   learning-side analogue of the serving stack's warm starts: the
//!   cached artifact nearest the request is specialized instead of
//!   recomputing from scratch.
//!
//! All derivations are exact integer arithmetic, so a consumer fed by
//! the cache produces *bit-identical* statistics, scores and CPTs to one
//! that counts rows directly (asserted by the equivalence suite in
//! `integration_learning.rs`).

use crate::core::{Dataset, VarId};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

/// Joint integer counts over a sorted set of variables, row-major with
/// the last variable fastest.
#[derive(Clone, Debug)]
pub struct ContingencyTable {
    /// Scope, sorted ascending (the canonical cache key).
    vars: Vec<VarId>,
    /// Cardinality per scope position.
    cards: Vec<usize>,
    /// `counts[idx]` where `idx = Σ digit_i * stride_i` (row-major).
    counts: Vec<u64>,
    /// Rows counted (the table always sums to this).
    n_rows: usize,
}

impl ContingencyTable {
    /// Count the joint table in one streaming pass over the dataset's
    /// columns. `vars` must be sorted and duplicate-free. Small arities
    /// get dedicated branch-free loops: 1–3 variables cover every CI
    /// test up to conditioning level 1 and most families, and the
    /// 4-variable path keeps conditioning level 2 — the hottest deep
    /// level in PC runs (§Perf P6) — off the generic per-row inner
    /// loop.
    pub fn count(data: &Dataset, vars: &[VarId]) -> ContingencyTable {
        debug_assert!(
            vars.windows(2).all(|w| w[0] < w[1]),
            "contingency scope must be sorted and unique"
        );
        let cards: Vec<usize> = vars.iter().map(|&v| data.cardinality(v)).collect();
        let size = cards.iter().product::<usize>().max(1);
        let mut counts = vec![0u64; size];
        let n = data.n_rows();
        match vars.len() {
            0 => counts[0] = n as u64,
            1 => {
                for &s in data.column(vars[0]) {
                    counts[s as usize] += 1;
                }
            }
            2 => {
                let c0 = data.column(vars[0]);
                let c1 = data.column(vars[1]);
                let k1 = cards[1];
                for r in 0..n {
                    counts[c0[r] as usize * k1 + c1[r] as usize] += 1;
                }
            }
            3 => {
                let c0 = data.column(vars[0]);
                let c1 = data.column(vars[1]);
                let c2 = data.column(vars[2]);
                let (k1, k2) = (cards[1], cards[2]);
                for r in 0..n {
                    let idx = (c0[r] as usize * k1 + c1[r] as usize) * k2
                        + c2[r] as usize;
                    counts[idx] += 1;
                }
            }
            4 => {
                let c0 = data.column(vars[0]);
                let c1 = data.column(vars[1]);
                let c2 = data.column(vars[2]);
                let c3 = data.column(vars[3]);
                let (k1, k2, k3) = (cards[1], cards[2], cards[3]);
                for r in 0..n {
                    let idx = ((c0[r] as usize * k1 + c1[r] as usize) * k2
                        + c2[r] as usize)
                        * k3
                        + c3[r] as usize;
                    counts[idx] += 1;
                }
            }
            _ => {
                // Mixed-radix index built per row; columns pre-fetched
                // once to keep the loop branch-free.
                let cols: Vec<&[u8]> = vars.iter().map(|&v| data.column(v)).collect();
                for r in 0..n {
                    let mut idx = 0usize;
                    for (k, col) in cols.iter().enumerate() {
                        idx = idx * cards[k] + col[r] as usize;
                    }
                    counts[idx] += 1;
                }
            }
        }
        ContingencyTable { vars: vars.to_vec(), cards, counts, n_rows: n }
    }

    /// Scope (sorted).
    pub fn vars(&self) -> &[VarId] {
        &self.vars
    }

    /// Cardinalities per scope position.
    pub fn cards(&self) -> &[usize] {
        &self.cards
    }

    /// Raw counts (row-major, last variable fastest).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Consume the table, yielding the raw counts without a copy — for
    /// owned tables whose canonical layout already is the wanted one.
    pub fn into_counts(self) -> Vec<u64> {
        self.counts
    }

    /// Rows the table was counted over.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Cell count.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Heap bytes of the count array (cache accounting).
    pub fn bytes(&self) -> u64 {
        (self.counts.len() * std::mem::size_of::<u64>()) as u64
    }

    /// Derive the marginal table over a subset of this table's scope by
    /// summing the dropped variables out — `O(cells)` exact integer
    /// sums, no dataset rescan. `vars` must be sorted and a subset of
    /// [`ContingencyTable::vars`].
    pub fn project(&self, vars: &[VarId]) -> ContingencyTable {
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(
            is_sorted_subset(vars, &self.vars),
            "projection scope must be a subset of the table scope"
        );
        let cards: Vec<usize> = vars
            .iter()
            .map(|&v| self.cards[self.vars.binary_search(&v).unwrap()])
            .collect();
        let size = cards.iter().product::<usize>().max(1);
        let mut counts = vec![0u64; size];
        // Row-major strides of the kept variables in the output (0 for a
        // dropped axis), then one odometer walk over the source cells.
        let mut out_strides = vec![0usize; self.vars.len()];
        let mut stride = 1usize;
        for (k, &v) in vars.iter().enumerate().rev() {
            let pos = self.vars.binary_search(&v).unwrap();
            out_strides[pos] = stride;
            stride *= cards[k];
        }
        self.scatter_into(&out_strides, &mut counts);
        ContingencyTable { vars: vars.to_vec(), cards, counts, n_rows: self.n_rows }
    }

    /// Counts re-laid-out with an explicit axis order (last axis
    /// fastest). `order` must be a permutation of the table scope; the
    /// consumers use it to turn the canonical sorted layout into their
    /// native one — `(parent config, child state)` for families,
    /// `(z config, x, y)` for CI tests — with exact integer scatter.
    pub fn permuted_counts(&self, order: &[VarId]) -> Vec<u64> {
        debug_assert_eq!(order.len(), self.vars.len(), "order must be a permutation");
        if order == self.vars {
            // Identity order (ascending scopes — the common case for
            // sorted conditioning sets and sorted parent lists): the
            // canonical layout already is the requested one.
            return self.counts.clone();
        }
        let mut out_strides = vec![0usize; self.vars.len()];
        let mut stride = 1usize;
        for &v in order.iter().rev() {
            let pos = self
                .vars
                .binary_search(&v)
                .expect("order must permute the table scope");
            out_strides[pos] = stride;
            stride *= self.cards[pos];
        }
        let mut out = vec![0u64; self.counts.len().max(1)];
        self.scatter_into(&out_strides, &mut out);
        out
    }

    /// Accumulate every cell into `out` at `Σ digit_i * out_strides[i]`
    /// — the shared walk behind projection and permutation.
    fn scatter_into(&self, out_strides: &[usize], out: &mut [u64]) {
        if self.vars.is_empty() {
            out[0] += self.counts[0];
            return;
        }
        let mut digits = vec![0usize; self.vars.len()];
        let mut idx = 0usize;
        for &c in &self.counts {
            if c > 0 {
                out[idx] += c;
            }
            // Odometer advance with incremental output index.
            let mut pos = digits.len();
            loop {
                if pos == 0 {
                    break;
                }
                pos -= 1;
                digits[pos] += 1;
                if digits[pos] < self.cards[pos] {
                    idx += out_strides[pos];
                    break;
                }
                digits[pos] = 0;
                idx -= out_strides[pos] * (self.cards[pos] - 1);
            }
        }
    }
}

/// Is sorted `a` a subset of sorted `b`? (Linear merge.)
fn is_sorted_subset(a: &[VarId], b: &[VarId]) -> bool {
    let mut i = 0;
    for &x in a {
        while i < b.len() && b[i] < x {
            i += 1;
        }
        if i == b.len() || b[i] != x {
            return false;
        }
        i += 1;
    }
    true
}

/// Counter snapshot of a [`CountCache`]. Every [`CountCache::table`]
/// call is counted exactly once: a `hit` (the exact table was cached), a
/// `projection` (derived from a cached superset — no row scan), or a
/// `scan` (cold streaming pass over the dataset).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CountCacheStats {
    pub hits: u64,
    pub projections: u64,
    pub scans: u64,
    /// Tables computed but not admitted (byte budget exhausted).
    pub skipped_admission: u64,
    /// Tables currently resident.
    pub tables: usize,
    /// Bytes of resident count arrays.
    pub bytes: u64,
}

impl CountCacheStats {
    /// Total lookups.
    pub fn lookups(&self) -> u64 {
        self.hits + self.projections + self.scans
    }

    /// Fraction of lookups that skipped the row scan entirely (exact
    /// hits only; projections are reported separately).
    pub fn hit_rate(&self) -> f64 {
        let total = self.lookups();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fraction of lookups answered without touching the dataset (hits
    /// plus projections).
    pub fn scan_free_rate(&self) -> f64 {
        let total = self.lookups();
        if total == 0 {
            0.0
        } else {
            (self.hits + self.projections) as f64 / total as f64
        }
    }

    /// Render this snapshot as registry samples under the
    /// `fastpgm_counts_*` families, one lookup counter per outcome
    /// (`hit` / `projection` / `scan`). `extra` labels (e.g. a `model`
    /// or `algo` tag) are prepended to every sample so several caches
    /// can publish side by side.
    pub fn to_samples(&self, extra: &crate::obs::Labels, out: &mut Vec<crate::obs::Sample>) {
        use crate::obs::Sample;
        let with = |outcome: &str| {
            let mut l = extra.clone();
            l.push(("outcome", outcome.to_string()));
            l
        };
        out.push(
            Sample::counter("fastpgm_counts_lookups_total", with("hit"), self.hits)
                .with_help("Count-cache lookups by outcome"),
        );
        out.push(Sample::counter(
            "fastpgm_counts_lookups_total",
            with("projection"),
            self.projections,
        ));
        out.push(Sample::counter("fastpgm_counts_lookups_total", with("scan"), self.scans));
        out.push(
            Sample::counter(
                "fastpgm_counts_skipped_admission_total",
                extra.clone(),
                self.skipped_admission,
            )
            .with_help("Tables computed but not admitted (byte budget exhausted)"),
        );
        out.push(
            Sample::gauge("fastpgm_counts_tables", extra.clone(), self.tables as f64)
                .with_help("Contingency tables currently resident"),
        );
        out.push(
            Sample::gauge("fastpgm_counts_bytes", extra.clone(), self.bytes as f64)
                .with_help("Bytes of resident count arrays"),
        );
    }

    /// Push this snapshot into `registry` (the publication style for a
    /// finished learning run; live caches should prefer a pull-style
    /// [`crate::obs::Collector`] wrapping [`CountCache::stats`]).
    pub fn publish(&self, registry: &crate::obs::Registry, extra: &crate::obs::Labels) {
        let mut samples = Vec::new();
        self.to_samples(extra, &mut samples);
        for s in samples {
            registry.push(s);
        }
    }
}

/// Shard count — a read-mostly workload (PC levels re-probe the same
/// pairs, hill climbing re-probes families) across at most
/// `default_threads()` workers; 16 shards keep write collisions rare
/// without bloating the struct.
const SHARDS: usize = 16;

/// Cap pooled per-table size indirectly via the byte budget; default 64
/// MiB of resident counts (tables beyond it are computed but not
/// cached). The PC reliability guard already bounds individual CI
/// tables to `n_rows / min_rows_per_cell` cells, so the budget is about
/// the *number* of resident tables, not runaway single allocations.
const DEFAULT_BYTE_BUDGET: u64 = 64 << 20;

/// One cache shard: sorted scope → shared table.
type Shard = RwLock<HashMap<Vec<VarId>, Arc<ContingencyTable>>>;

/// A thread-safe, sharded cache of [`ContingencyTable`]s keyed on
/// sorted variable sets, bound to one dataset.
///
/// * **Hits** are shard-local read locks — the hot path of repeated CI
///   tests and family re-scores never serializes across shards.
/// * **Misses** consult an inverted `var → tables` index for the
///   smallest affordable cached *superset* and project from it
///   ([`ContingencyTable::project`]) before falling back to a row scan.
/// * Admission is bounded by a byte budget; over budget the table is
///   still returned, just not retained (no eviction machinery — see
///   ROADMAP for the ADTree-style hierarchical follow-up).
pub struct CountCache {
    shards: Vec<Shard>,
    /// `var → cached tables containing it`, consulted only on misses
    /// (which are about to pay a table-sized or row-sized pass anyway).
    superset_index: Mutex<HashMap<VarId, Vec<Arc<ContingencyTable>>>>,
    /// Shape fingerprint `(n_rows, n_vars, cardinality hash)` of the
    /// dataset this cache is bound to, set by the first lookup. A cache
    /// serves exactly one dataset — mixing datasets would silently
    /// return the first one's counts — so every lookup asserts the
    /// fingerprint (cheap: `O(n_vars)` hashing next to an
    /// `O(n_rows)`-or-table-sized count derivation).
    bound: OnceLock<(usize, usize, u64)>,
    byte_budget: u64,
    bytes: AtomicU64,
    tables: AtomicU64,
    hits: AtomicU64,
    projections: AtomicU64,
    scans: AtomicU64,
    skipped_admission: AtomicU64,
}

impl Default for CountCache {
    fn default() -> Self {
        Self::new()
    }
}

impl CountCache {
    /// Cache with the default 64 MiB admission budget.
    pub fn new() -> Self {
        Self::with_budget(DEFAULT_BYTE_BUDGET)
    }

    /// Cache with an explicit byte budget for resident count arrays.
    pub fn with_budget(byte_budget: u64) -> Self {
        CountCache {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            superset_index: Mutex::new(HashMap::new()),
            bound: OnceLock::new(),
            byte_budget,
            bytes: AtomicU64::new(0),
            tables: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            projections: AtomicU64::new(0),
            scans: AtomicU64::new(0),
            skipped_admission: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, vars: &[VarId]) -> usize {
        let mut h = DefaultHasher::new();
        vars.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Shape fingerprint of a dataset (rows, variable count, cardinality
    /// hash) — the binding check of [`CountCache::table`].
    fn fingerprint(data: &Dataset) -> (usize, usize, u64) {
        let mut h = DefaultHasher::new();
        for v in data.variables() {
            v.cardinality.hash(&mut h);
        }
        (data.n_rows(), data.n_vars(), h.finish())
    }

    /// The joint count table over `vars` (sorted, duplicate-free) —
    /// cached, projected from a cached superset, or counted by one
    /// streaming pass. The returned table is shared; never mutate it.
    ///
    /// A cache is bound to the first dataset it sees: a lookup against a
    /// shape-incompatible dataset panics rather than silently returning
    /// the bound dataset's counts. (Same-shape distinct datasets — e.g.
    /// two equally sized samples — are indistinguishable by this guard;
    /// the contract stays one cache per dataset.)
    pub fn table(&self, data: &Dataset, vars: &[VarId]) -> Arc<ContingencyTable> {
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]), "cache key must be sorted");
        let fp = Self::fingerprint(data);
        let bound = self.bound.get_or_init(|| fp);
        assert_eq!(
            *bound, fp,
            "CountCache serves exactly one dataset (bound shape {bound:?}, got {fp:?})"
        );
        let shard = &self.shards[self.shard_of(vars)];
        if let Some(t) = shard.read().unwrap().get(vars) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(t);
        }

        // Miss: project from the smallest affordable cached superset, or
        // scan. Projection costs O(superset cells); a row scan costs
        // O(n_rows · |vars|). The 4× slack keeps borderline projections
        // (dense superset, few rows) from losing to the scan they avoid.
        let table = match self.projection_base(vars, data.n_rows().saturating_mul(4)) {
            Some(base) => {
                self.projections.fetch_add(1, Ordering::Relaxed);
                Arc::new(base.project(vars))
            }
            None => {
                self.scans.fetch_add(1, Ordering::Relaxed);
                Arc::new(ContingencyTable::count(data, vars))
            }
        };
        self.admit(vars, &table);
        table
    }

    /// Smallest cached strict superset of `vars` with at most
    /// `max_cells` cells, if any.
    fn projection_base(
        &self,
        vars: &[VarId],
        max_cells: usize,
    ) -> Option<Arc<ContingencyTable>> {
        if vars.is_empty() {
            return None;
        }
        let index = self.superset_index.lock().unwrap();
        let bucket = index.get(&vars[0])?;
        let mut best: Option<&Arc<ContingencyTable>> = None;
        for cand in bucket {
            if cand.len() <= max_cells
                && cand.vars().len() > vars.len()
                && best.is_none_or(|b| cand.len() < b.len())
                && is_sorted_subset(vars, cand.vars())
            {
                best = Some(cand);
            }
        }
        best.cloned()
    }

    /// Store a freshly computed table unless the byte budget is spent.
    /// A racing duplicate keeps the first insert (the tables are equal).
    fn admit(&self, vars: &[VarId], table: &Arc<ContingencyTable>) {
        let bytes = table.bytes();
        // Reserve the bytes with a compare-and-swap before inserting, so
        // concurrent admissions cannot collectively overshoot the budget
        // (a plain check-then-add would admit up to one extra table per
        // in-flight worker).
        let reserved = self.bytes.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| (cur + bytes <= self.byte_budget).then_some(cur + bytes),
        );
        if reserved.is_err() {
            self.skipped_admission.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let shard = &self.shards[self.shard_of(vars)];
        {
            let mut map = shard.write().unwrap();
            if map.contains_key(vars) {
                // Lost the race to an equal table: release the
                // reservation, keep the resident one. Saturating — a
                // concurrent `clear` may already have zeroed the
                // counter, and a wrapped u64 would poison admission
                // forever.
                let _ = self.bytes.fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |cur| Some(cur.saturating_sub(bytes)),
                );
                return;
            }
            map.insert(vars.to_vec(), Arc::clone(table));
        }
        self.tables.fetch_add(1, Ordering::Relaxed);
        let mut index = self.superset_index.lock().unwrap();
        for &v in table.vars() {
            index.entry(v).or_default().push(Arc::clone(table));
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CountCacheStats {
        CountCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            projections: self.projections.load(Ordering::Relaxed),
            scans: self.scans.load(Ordering::Relaxed),
            skipped_admission: self.skipped_admission.load(Ordering::Relaxed),
            tables: self.tables.load(Ordering::Relaxed) as usize,
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }

    /// Resident table count.
    pub fn len(&self) -> usize {
        self.tables.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every resident table (counters are kept, and the cache
    /// stays bound to its dataset). Concurrent lookups remain safe: an
    /// in-flight admission racing the clear at worst re-admits its table
    /// against the emptied maps, and byte accounting saturates rather
    /// than wrapping.
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
        self.superset_index.lock().unwrap().clear();
        self.bytes.store(0, Ordering::Relaxed);
        self.tables.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Variable;
    use crate::rng::Pcg;

    fn toy(n: usize, seed: u64) -> Dataset {
        let vars = vec![
            Variable::new("a", 2),
            Variable::new("b", 3),
            Variable::new("c", 2),
            Variable::new("d", 4),
            Variable::new("e", 3),
        ];
        let mut rng = Pcg::seed_from(seed);
        let mut ds = Dataset::new(vars);
        for _ in 0..n {
            ds.push_row(&[
                rng.below(2) as u8,
                rng.below(3) as u8,
                rng.below(2) as u8,
                rng.below(4) as u8,
                rng.below(3) as u8,
            ]);
        }
        ds
    }

    #[test]
    fn counts_match_manual() {
        let ds = toy(500, 1);
        let t = ContingencyTable::count(&ds, &[0, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.counts().iter().sum::<u64>(), 500);
        let mut manual = [0u64; 4];
        for r in 0..ds.n_rows() {
            manual[ds.value(r, 0) * 2 + ds.value(r, 2)] += 1;
        }
        assert_eq!(t.counts(), &manual);
    }

    #[test]
    fn empty_scope_counts_rows() {
        let ds = toy(77, 2);
        let t = ContingencyTable::count(&ds, &[]);
        assert_eq!(t.counts(), &[77]);
    }

    #[test]
    fn arity_paths_agree() {
        // The dedicated 1/2/3/4-var loops must equal the generic path;
        // the generic path is exercised with 5 vars, all cross-checked
        // against a row-wise manual count.
        let ds = toy(400, 3);
        for vars in [
            vec![1],
            vec![0, 3],
            vec![0, 1, 2],
            vec![0, 1, 2, 3],
            vec![0, 1, 2, 3, 4],
        ] {
            let t = ContingencyTable::count(&ds, &vars);
            let cards: Vec<usize> =
                vars.iter().map(|&v| ds.cardinality(v)).collect();
            let mut manual = vec![0u64; t.len()];
            for r in 0..ds.n_rows() {
                let mut idx = 0usize;
                for (k, &v) in vars.iter().enumerate() {
                    idx = idx * cards[k] + ds.value(r, v);
                }
                manual[idx] += 1;
            }
            assert_eq!(t.counts(), &manual[..], "vars {vars:?}");
        }
    }

    #[test]
    fn projection_equals_rescan() {
        let ds = toy(600, 4);
        let full = ContingencyTable::count(&ds, &[0, 1, 2, 3]);
        for sub in [vec![0], vec![1, 3], vec![0, 2], vec![0, 1, 2], Vec::new()] {
            let projected = full.project(&sub);
            let direct = ContingencyTable::count(&ds, &sub);
            assert_eq!(projected.counts(), direct.counts(), "subset {sub:?}");
            assert_eq!(projected.vars(), direct.vars());
            assert_eq!(projected.cards(), direct.cards());
        }
    }

    #[test]
    fn permuted_counts_relayouts_exactly() {
        let ds = toy(300, 5);
        let t = ContingencyTable::count(&ds, &[0, 1, 3]);
        // Target layout (d, a, b): idx = (d * 2 + a) * 3 + b.
        let p = t.permuted_counts(&[3, 0, 1]);
        let mut manual = vec![0u64; p.len()];
        for r in 0..ds.n_rows() {
            manual[(ds.value(r, 3) * 2 + ds.value(r, 0)) * 3 + ds.value(r, 1)] += 1;
        }
        assert_eq!(p, manual);
        // Identity order reproduces the raw counts.
        assert_eq!(t.permuted_counts(&[0, 1, 3]), t.counts());
    }

    #[test]
    fn cache_hits_and_projections_counted() {
        let ds = toy(500, 6);
        let cache = CountCache::new();
        let a = cache.table(&ds, &[0, 1, 2]);
        assert_eq!(cache.stats().scans, 1);
        let b = cache.table(&ds, &[0, 1, 2]);
        assert!(Arc::ptr_eq(&a, &b), "hit must share the resident table");
        assert_eq!(cache.stats().hits, 1);
        // Subset of a cached table: projected, not rescanned.
        let sub = cache.table(&ds, &[0, 2]);
        let stats = cache.stats();
        assert_eq!(stats.projections, 1, "{stats:?}");
        assert_eq!(stats.scans, 1, "{stats:?}");
        assert_eq!(sub.counts(), ContingencyTable::count(&ds, &[0, 2]).counts());
        assert!(stats.hit_rate() > 0.0 && stats.scan_free_rate() > stats.hit_rate());
    }

    #[test]
    fn cache_prefers_smallest_superset() {
        let ds = toy(500, 7);
        let cache = CountCache::new();
        cache.table(&ds, &[0, 1, 2, 3]); // 48 cells
        cache.table(&ds, &[0, 1, 2]); // 12 cells (projected from above)
        let before = cache.stats().projections;
        let t = cache.table(&ds, &[0, 1]);
        assert_eq!(cache.stats().projections, before + 1);
        assert_eq!(t.counts(), ContingencyTable::count(&ds, &[0, 1]).counts());
    }

    #[test]
    fn admission_budget_skips_but_still_answers() {
        let ds = toy(200, 8);
        let cache = CountCache::with_budget(0);
        let t = cache.table(&ds, &[0, 1]);
        assert_eq!(t.counts().iter().sum::<u64>(), 200);
        let stats = cache.stats();
        assert_eq!(stats.tables, 0);
        assert_eq!(stats.skipped_admission, 1);
        // Nothing cached: the repeat is another scan, never a panic.
        cache.table(&ds, &[0, 1]);
        assert_eq!(cache.stats().scans, 2);
    }

    #[test]
    #[should_panic(expected = "exactly one dataset")]
    fn cache_rejects_shape_incompatible_dataset() {
        let a = toy(100, 10);
        let cache = CountCache::new();
        cache.table(&a, &[0]);
        // Different row count: the binding guard must fire instead of
        // silently serving dataset `a`'s counts.
        let b = toy(150, 11);
        cache.table(&b, &[0]);
    }

    #[test]
    fn clear_drops_tables_keeps_counters() {
        let ds = toy(100, 9);
        let cache = CountCache::new();
        cache.table(&ds, &[0, 1]);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats().scans, 1);
        // Post-clear lookups re-count (no stale superset index entries).
        let t = cache.table(&ds, &[0]);
        assert_eq!(t.counts(), ContingencyTable::count(&ds, &[0]).counts());
        assert_eq!(cache.stats().scans, 2);
    }

    #[test]
    fn sorted_subset_checks() {
        assert!(is_sorted_subset(&[], &[1, 2]));
        assert!(is_sorted_subset(&[1, 3], &[0, 1, 3, 5]));
        assert!(!is_sorted_subset(&[1, 4], &[0, 1, 3, 5]));
        assert!(!is_sorted_subset(&[1], &[]));
    }
}
