//! Batched class-posterior scoring.
//!
//! [`Scorer`] abstracts the batched "evidence rows → class posteriors"
//! operation so the coordinator can run against either the real
//! XLA-compiled artifact ([`BatchScorer`]) or the pure-Rust reference
//! ([`ReferenceScorer`], also the oracle the integration tests compare
//! the XLA path against).

use crate::network::BayesianNetwork;
use anyhow::Result;
#[cfg(feature = "xla-runtime")]
use anyhow::Context;
#[cfg(feature = "xla-runtime")]
use super::ArtifactBundle;
#[cfg(feature = "xla-runtime")]
use super::ArtifactMeta;

/// Batched classification scoring.
///
/// Deliberately **not** `Send`/`Sync`: the PJRT client and executable are
/// thread-affine (`Rc` internals), so [`BatchScorer`] must live on the
/// thread that created it. The coordinator's [`crate::coordinator::DynamicBatcher`]
/// therefore takes a *factory* and constructs the scorer on its worker
/// thread.
pub trait Scorer {
    /// Native batch size (requests are padded up to it).
    fn batch_size(&self) -> usize;
    fn n_classes(&self) -> usize;
    fn n_vars(&self) -> usize;
    fn class_var(&self) -> usize;
    /// Posterior over classes for each row. `rows.len() <= batch_size()`;
    /// each row has `n_vars()` state indices (the class column is
    /// ignored).
    fn score(&self, rows: &[Vec<u8>]) -> Result<Vec<Vec<f64>>>;
}

/// The real thing: PJRT CPU client executing the AOT HLO. Only built with
/// the `xla-runtime` feature — the default build has no PJRT dependency
/// (CI runners carry no artifacts), and the vendored `xla` stub keeps this
/// code compiling everywhere the feature is enabled.
#[cfg(feature = "xla-runtime")]
pub struct BatchScorer {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
    /// The network the artifact was compiled from (for cross-checks).
    pub net: BayesianNetwork,
}

#[cfg(feature = "xla-runtime")]
impl BatchScorer {
    /// Load an artifact bundle: parse the network, read + compile the HLO.
    pub fn load(bundle: &ArtifactBundle) -> Result<BatchScorer> {
        let meta = bundle.read_meta()?;
        let net = crate::io::fpgm::load(&bundle.fpgm)?;
        anyhow::ensure!(
            net.n_vars() == meta.n_vars,
            "fpgm/meta disagree on variable count"
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            bundle.hlo.to_str().context("non-utf8 path")?,
        )
        .context("parsing HLO text")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO")?;
        Ok(BatchScorer { exe, meta, net })
    }

    /// Convert log-joint scores to normalized posteriors (stable softmax).
    fn softmax_rows(logits: &[f32], n: usize, k: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|b| {
                let row = &logits[b * k..(b + 1) * k];
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f64> =
                    row.iter().map(|&x| ((x - m) as f64).exp()).collect();
                let s: f64 = exps.iter().sum();
                exps.into_iter().map(|e| e / s).collect()
            })
            .collect()
    }
}

#[cfg(feature = "xla-runtime")]
impl Scorer for BatchScorer {
    fn batch_size(&self) -> usize {
        self.meta.batch
    }

    fn n_classes(&self) -> usize {
        self.meta.n_classes
    }

    fn n_vars(&self) -> usize {
        self.meta.n_vars
    }

    fn class_var(&self) -> usize {
        self.meta.class_var
    }

    fn score(&self, rows: &[Vec<u8>]) -> Result<Vec<Vec<f64>>> {
        let b = self.meta.batch;
        let n = self.meta.n_vars;
        let k = self.meta.n_classes;
        anyhow::ensure!(rows.len() <= b, "batch overflow: {} > {b}", rows.len());
        // Pack + pad to the artifact's static batch shape.
        let mut states = vec![0i32; b * n];
        for (i, row) in rows.iter().enumerate() {
            anyhow::ensure!(row.len() == n, "row arity mismatch");
            for (j, &s) in row.iter().enumerate() {
                states[i * n + j] = s as i32;
            }
        }
        let input = xla::Literal::vec1(&states).reshape(&[b as i64, n as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let logits = out.to_vec::<f32>()?;
        anyhow::ensure!(logits.len() == b * k, "unexpected output size");
        Ok(Self::softmax_rows(&logits, rows.len(), k))
    }
}

/// Pure-Rust reference scorer: same contract, computed from the network's
/// CPTs directly. Used as the test oracle for the XLA path and as the
/// baseline in bench E9.
pub struct ReferenceScorer {
    pub net: BayesianNetwork,
    pub class_var: usize,
    batch: usize,
}

impl ReferenceScorer {
    pub fn new(net: BayesianNetwork, class_var: usize, batch: usize) -> Self {
        ReferenceScorer { net, class_var, batch }
    }

    /// Log-joint of a complete row.
    fn log_joint(&self, row: &[u8]) -> f64 {
        let mut a = crate::core::Assignment::from_values(row.to_vec());
        // (Assignment is over all vars; row already complete.)
        let mut ll = 0.0;
        for v in 0..self.net.n_vars() {
            let cpt = self.net.cpt(v);
            let cfg = cpt.parent_config(&a);
            ll += cpt.prob(cfg, a.get(v)).max(1e-30).ln();
        }
        // keep the borrow checker happy about `a` mutation pattern
        let _ = &mut a;
        ll
    }
}

impl Scorer for ReferenceScorer {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn n_classes(&self) -> usize {
        self.net.cardinality(self.class_var)
    }

    fn n_vars(&self) -> usize {
        self.net.n_vars()
    }

    fn class_var(&self) -> usize {
        self.class_var
    }

    fn score(&self, rows: &[Vec<u8>]) -> Result<Vec<Vec<f64>>> {
        let k = self.n_classes();
        Ok(rows
            .iter()
            .map(|row| {
                let mut scores = Vec::with_capacity(k);
                let mut work = row.clone();
                for c in 0..k {
                    work[self.class_var] = c as u8;
                    scores.push(self.log_joint(&work));
                }
                let m = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = scores.iter().map(|&s| (s - m).exp()).collect();
                let t: f64 = exps.iter().sum();
                exps.into_iter().map(|e| e / t).collect()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Evidence;
    use crate::network::repository;

    #[test]
    fn reference_scorer_matches_brute_force() {
        let net = repository::asia();
        let class_var = net.var_index("bronc").unwrap();
        let scorer = ReferenceScorer::new(net.clone(), class_var, 8);
        let row = vec![0u8, 0, 1, 0, 0, 0, 1, 1];
        let post = scorer.score(&[row.clone()]).unwrap().pop().unwrap();
        // Compare against brute force with all other vars as evidence.
        let ev: Evidence = (0..net.n_vars())
            .filter(|&v| v != class_var)
            .map(|v| (v, row[v] as usize))
            .collect();
        let expect = net.brute_force_posterior(class_var, &ev);
        for (a, b) in post.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-9, "{post:?} vs {expect:?}");
        }
    }

    #[test]
    fn reference_scorer_batch() {
        let net = repository::cancer();
        let scorer = ReferenceScorer::new(net, 2, 16);
        let rows: Vec<Vec<u8>> =
            (0..5).map(|i| vec![i % 2, (i / 2) % 2, 0, 1, 0]).collect();
        let posts = scorer.score(&rows).unwrap();
        assert_eq!(posts.len(), 5);
        for p in posts {
            assert_eq!(p.len(), 2);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }
}
