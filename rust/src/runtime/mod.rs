//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once by `make
//! artifacts`) lowers the L2 JAX classification model — whose hot spot is
//! the L1 Pallas batched log-likelihood kernel — to **HLO text** (the
//! interchange format xla_extension 0.5.1 accepts; serialized protos from
//! jax ≥ 0.5 are rejected, see DESIGN.md). This module compiles that text
//! on the PJRT CPU client and executes it from the Rust request path:
//! Python is never loaded at runtime.
//!
//! Artifact bundle on disk (per network):
//! * `<name>.fpgm`        — the network (shared parser with Python)
//! * `<name>_meta.txt`    — key/value lines: `batch`, `n_vars`,
//!   `class_var`, `n_classes`
//! * `<name>_classify_b<batch>.hlo.txt` — HLO: `i32[B,N] -> f32[B,K]`
//!   (log-joint per class; rows = evidence with the class column ignored)

mod scorer;

#[cfg(feature = "xla-runtime")]
pub use scorer::BatchScorer;
pub use scorer::{ReferenceScorer, Scorer};

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `_meta.txt`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub network: String,
    pub batch: usize,
    pub n_vars: usize,
    pub class_var: usize,
    pub n_classes: usize,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once(char::is_whitespace)
                .with_context(|| format!("bad meta line {line:?}"))?;
            kv.insert(k.to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).with_context(|| format!("meta missing key {k:?}"))
        };
        Ok(ArtifactMeta {
            network: get("network")?.clone(),
            batch: get("batch")?.parse().context("batch")?,
            n_vars: get("n_vars")?.parse().context("n_vars")?,
            class_var: get("class_var")?.parse().context("class_var")?,
            n_classes: get("n_classes")?.parse().context("n_classes")?,
        })
    }
}

/// Paths of one artifact bundle.
#[derive(Clone, Debug)]
pub struct ArtifactBundle {
    pub name: String,
    pub fpgm: PathBuf,
    pub meta: PathBuf,
    pub hlo: PathBuf,
}

impl ArtifactBundle {
    /// Locate the bundle for `name` under `dir` (default `artifacts/`).
    pub fn locate(dir: &Path, name: &str) -> Result<ArtifactBundle> {
        let fpgm = dir.join(format!("{name}.fpgm"));
        let meta = dir.join(format!("{name}_meta.txt"));
        if !meta.exists() {
            bail!(
                "artifact meta {} not found — run `make artifacts` first",
                meta.display()
            );
        }
        let meta_parsed =
            ArtifactMeta::parse(&std::fs::read_to_string(&meta)?)?;
        let hlo = dir.join(format!(
            "{name}_classify_b{}.hlo.txt",
            meta_parsed.batch
        ));
        if !fpgm.exists() || !hlo.exists() {
            bail!("incomplete artifact bundle for {name} in {}", dir.display());
        }
        Ok(ArtifactBundle { name: name.to_string(), fpgm, meta, hlo })
    }

    /// All bundles in a directory (by scanning `_meta.txt` files).
    pub fn discover(dir: &Path) -> Result<Vec<ArtifactBundle>> {
        let mut out = Vec::new();
        if !dir.exists() {
            return Ok(out);
        }
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(name) = fname.strip_suffix("_meta.txt") {
                    if let Ok(b) = ArtifactBundle::locate(dir, name) {
                        out.push(b);
                    }
                }
            }
        }
        out.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(out)
    }

    pub fn read_meta(&self) -> Result<ArtifactMeta> {
        ArtifactMeta::parse(&std::fs::read_to_string(&self.meta)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let m = ArtifactMeta::parse(
            "# comment\nnetwork asia\nbatch 256\nn_vars 8\nclass_var 7\nn_classes 2\n",
        )
        .unwrap();
        assert_eq!(m.network, "asia");
        assert_eq!(m.batch, 256);
        assert_eq!(m.class_var, 7);
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(ArtifactMeta::parse("network x\nbatch 4\n").is_err());
        assert!(ArtifactMeta::parse("garbage-without-space\n").is_err());
    }

    #[test]
    fn locate_missing_dir_errors() {
        let r = ArtifactBundle::locate(Path::new("/nonexistent"), "foo");
        assert!(r.is_err());
    }

    #[test]
    fn discover_empty_dir_ok() {
        let out = ArtifactBundle::discover(Path::new("/nonexistent")).unwrap();
        assert!(out.is_empty());
    }
}
