//! Minimal command-line parsing (the offline image has no clap crate).
//!
//! Supports `program subcommand [--flag value] [--switch] positional...`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or boolean switch.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn parse_flag<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("learn --alpha 0.05 --threads 4 data.csv");
        assert_eq!(a.subcommand.as_deref(), Some("learn"));
        assert_eq!(a.flag("alpha"), Some("0.05"));
        assert_eq!(a.parse_flag("threads", 1usize), 4);
        assert_eq!(a.positional, vec!["data.csv"]);
    }

    #[test]
    fn eq_style_and_switches() {
        let a = parse("bench --net=asia --verbose");
        assert_eq!(a.flag("net"), Some("asia"));
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn missing_flag_defaults() {
        let a = parse("infer");
        assert_eq!(a.parse_flag("samples", 100usize), 100);
        assert_eq!(a.flag_or("engine", "jt"), "jt");
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.switch("help"));
    }
}
