//! Built-in classic Bayesian networks.
//!
//! The small standards (sprinkler, cancer, earthquake, asia, survey) are
//! embedded with their published CPTs; they are the correctness anchors of
//! the test suite (small enough for the brute-force oracle) and the small
//! end of every benchmark sweep. Larger repository networks (CHILD,
//! INSURANCE, ALARM, HEPAR2) are *not* redistributable as exact tables
//! here; [`super::synthetic`] generates structurally matched stand-ins
//! (see DESIGN.md §Substitutions).
//!
//! State convention: binary variables use `[no, yes]` (index 0 = no).

use super::synthetic::SyntheticSpec;
use super::{BayesianNetwork, NetworkBuilder};
use crate::core::Variable;

/// Names of all built-in networks, for CLI listings.
pub const BUILTIN_NAMES: [&str; 5] =
    ["sprinkler", "cancer", "earthquake", "asia", "survey"];

/// Load a built-in network by name.
pub fn by_name(name: &str) -> Option<BayesianNetwork> {
    match name {
        "sprinkler" => Some(sprinkler()),
        "cancer" => Some(cancer()),
        "earthquake" => Some(earthquake()),
        "asia" => Some(asia()),
        "survey" => Some(survey()),
        _ => None,
    }
}

/// Synthetic stand-in presets (see [`super::synthetic`]): name →
/// constructor, the single source of truth for both name listings and
/// [`by_name_extended`] resolution. Generated with a fixed seed so every
/// resolver call yields the same parameters.
pub const SYNTHETIC_PRESETS: [(&str, fn() -> BayesianNetwork); 5] = [
    ("child_like", || SyntheticSpec::child_like().generate(1)),
    ("insurance_like", || SyntheticSpec::insurance_like().generate(1)),
    ("alarm_like", || SyntheticSpec::alarm_like().generate(1)),
    ("hepar2_like", || SyntheticSpec::hepar2_like().generate(1)),
    ("win95pts_like", || SyntheticSpec::win95pts_like().generate(1)),
];

/// Resolve a built-in network *or* a synthetic preset by name — the full
/// set of networks the serving layer (CLI `serve-query`, benches, the
/// e2e example) can host without any on-disk artifacts.
pub fn by_name_extended(name: &str) -> Option<BayesianNetwork> {
    if let Some(net) = by_name(name) {
        return Some(net);
    }
    SYNTHETIC_PRESETS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, generate)| generate())
}

/// The 4-node sprinkler network (Russell & Norvig / Murphy's BNT example).
///
/// `cloudy -> sprinkler`, `cloudy -> rain`, `sprinkler -> wet`, `rain -> wet`.
pub fn sprinkler() -> BayesianNetwork {
    NetworkBuilder::new("sprinkler")
        .variable(Variable::binary("cloudy"))    // 0
        .variable(Variable::binary("sprinkler")) // 1
        .variable(Variable::binary("rain"))      // 2
        .variable(Variable::binary("wet"))       // 3
        .edge("cloudy", "sprinkler")
        .edge("cloudy", "rain")
        .edge("sprinkler", "wet")
        .edge("rain", "wet")
        .cpt("cloudy", vec![0.5, 0.5])
        // P(sprinkler | cloudy): cloudy=no -> 0.5 on, cloudy=yes -> 0.1 on
        .cpt("sprinkler", vec![0.5, 0.5, 0.9, 0.1])
        // P(rain | cloudy): no -> 0.2, yes -> 0.8
        .cpt("rain", vec![0.8, 0.2, 0.2, 0.8])
        // P(wet | sprinkler, rain) rows over (s, r) with r fastest:
        // (no,no)=0.0, (no,yes)=0.9, (yes,no)=0.9, (yes,yes)=0.99
        .cpt("wet", vec![
            1.0, 0.0,
            0.1, 0.9,
            0.1, 0.9,
            0.01, 0.99,
        ])
        .build()
}

/// The 5-node CANCER network (Korb & Nicholson).
pub fn cancer() -> BayesianNetwork {
    NetworkBuilder::new("cancer")
        .variable(Variable::with_states("pollution", ["low", "high"])) // 0
        .variable(Variable::binary("smoker"))                          // 1
        .variable(Variable::binary("cancer"))                          // 2
        .variable(Variable::binary("xray"))                            // 3
        .variable(Variable::binary("dyspnoea"))                        // 4
        .edge("pollution", "cancer")
        .edge("smoker", "cancer")
        .edge("cancer", "xray")
        .edge("cancer", "dyspnoea")
        .cpt("pollution", vec![0.9, 0.1])
        .cpt("smoker", vec![0.7, 0.3])
        // P(cancer=yes | pollution, smoker), smoker fastest:
        // (low,no)=0.001 (low,yes)=0.03 (high,no)=0.02 (high,yes)=0.05
        .cpt("cancer", vec![
            0.999, 0.001,
            0.97, 0.03,
            0.98, 0.02,
            0.95, 0.05,
        ])
        // P(xray=pos | cancer): no -> 0.2, yes -> 0.9
        .cpt("xray", vec![0.8, 0.2, 0.1, 0.9])
        // P(dyspnoea=yes | cancer): no -> 0.3, yes -> 0.65
        .cpt("dyspnoea", vec![0.7, 0.3, 0.35, 0.65])
        .build()
}

/// The 5-node EARTHQUAKE network (Pearl's burglar alarm).
pub fn earthquake() -> BayesianNetwork {
    NetworkBuilder::new("earthquake")
        .variable(Variable::binary("burglary"))   // 0
        .variable(Variable::binary("earthquake")) // 1
        .variable(Variable::binary("alarm"))      // 2
        .variable(Variable::binary("johncalls"))  // 3
        .variable(Variable::binary("marycalls"))  // 4
        .edge("burglary", "alarm")
        .edge("earthquake", "alarm")
        .edge("alarm", "johncalls")
        .edge("alarm", "marycalls")
        .cpt("burglary", vec![0.999, 0.001])
        .cpt("earthquake", vec![0.998, 0.002])
        // P(alarm=yes | burglary, earthquake), earthquake fastest:
        // (no,no)=0.001 (no,yes)=0.29 (yes,no)=0.94 (yes,yes)=0.95
        .cpt("alarm", vec![
            0.999, 0.001,
            0.71, 0.29,
            0.06, 0.94,
            0.05, 0.95,
        ])
        .cpt("johncalls", vec![0.95, 0.05, 0.10, 0.90])
        .cpt("marycalls", vec![0.99, 0.01, 0.30, 0.70])
        .build()
}

/// The 8-node ASIA network (Lauritzen & Spiegelhalter 1988) — the original
/// junction-tree paper's example and the canonical small benchmark.
pub fn asia() -> BayesianNetwork {
    NetworkBuilder::new("asia")
        .variable(Variable::binary("asia"))   // 0 visit to Asia
        .variable(Variable::binary("tub"))    // 1 tuberculosis
        .variable(Variable::binary("smoke"))  // 2 smoking
        .variable(Variable::binary("lung"))   // 3 lung cancer
        .variable(Variable::binary("bronc"))  // 4 bronchitis
        .variable(Variable::binary("either")) // 5 tub or lung
        .variable(Variable::binary("xray"))   // 6 positive x-ray
        .variable(Variable::binary("dysp"))   // 7 dyspnoea
        .edge("asia", "tub")
        .edge("smoke", "lung")
        .edge("smoke", "bronc")
        .edge("tub", "either")
        .edge("lung", "either")
        .edge("either", "xray")
        .edge("bronc", "dysp")
        .edge("either", "dysp")
        .cpt("asia", vec![0.99, 0.01])
        // P(tub=yes | asia): no -> 0.01, yes -> 0.05
        .cpt("tub", vec![0.99, 0.01, 0.95, 0.05])
        .cpt("smoke", vec![0.5, 0.5])
        // P(lung=yes | smoke): no -> 0.01, yes -> 0.1
        .cpt("lung", vec![0.99, 0.01, 0.9, 0.1])
        // P(bronc=yes | smoke): no -> 0.3, yes -> 0.6
        .cpt("bronc", vec![0.7, 0.3, 0.4, 0.6])
        // either = tub OR lung (deterministic); parents sorted (tub=1, lung=3),
        // lung fastest: (t=no,l=no) (no,yes) (yes,no) (yes,yes)
        .cpt("either", vec![
            1.0, 0.0,
            0.0, 1.0,
            0.0, 1.0,
            0.0, 1.0,
        ])
        // P(xray=yes | either): no -> 0.05, yes -> 0.98
        .cpt("xray", vec![0.95, 0.05, 0.02, 0.98])
        // P(dysp=yes | bronc, either) parents sorted (bronc=4, either=5),
        // either fastest: (b=no,e=no)=0.1 (no,yes)=0.7 (yes,no)=0.8 (yes,yes)=0.9
        .cpt("dysp", vec![
            0.9, 0.1,
            0.3, 0.7,
            0.2, 0.8,
            0.1, 0.9,
        ])
        .build()
}

/// The 6-node SURVEY network (Scutari's bnlearn tutorial network) —
/// includes a ternary variable, exercising non-binary cardinalities.
pub fn survey() -> BayesianNetwork {
    NetworkBuilder::new("survey")
        .variable(Variable::with_states("age", ["young", "adult", "old"])) // 0
        .variable(Variable::with_states("sex", ["m", "f"]))                // 1
        .variable(Variable::with_states("edu", ["high", "uni"]))           // 2
        .variable(Variable::with_states("occ", ["emp", "self"]))           // 3
        .variable(Variable::with_states("res", ["small", "big"]))          // 4
        .variable(Variable::with_states("travel", ["car", "train", "other"])) // 5
        .edge("age", "edu")
        .edge("sex", "edu")
        .edge("edu", "occ")
        .edge("edu", "res")
        .edge("occ", "travel")
        .edge("res", "travel")
        .cpt("age", vec![0.3, 0.5, 0.2])
        .cpt("sex", vec![0.6, 0.4])
        // P(edu | age, sex), sex fastest; rows (age,sex):
        // (y,m) (y,f) (a,m) (a,f) (o,m) (o,f)
        .cpt("edu", vec![
            0.75, 0.25,
            0.64, 0.36,
            0.72, 0.28,
            0.70, 0.30,
            0.88, 0.12,
            0.90, 0.10,
        ])
        // P(occ | edu): high -> emp 0.96, uni -> emp 0.92
        .cpt("occ", vec![0.96, 0.04, 0.92, 0.08])
        // P(res | edu): high -> small 0.25, uni -> small 0.20
        .cpt("res", vec![0.25, 0.75, 0.20, 0.80])
        // P(travel | occ, res), res fastest; rows (occ,res):
        // (emp,small) (emp,big) (self,small) (self,big)
        .cpt("travel", vec![
            0.48, 0.42, 0.10,
            0.58, 0.24, 0.18,
            0.56, 0.36, 0.08,
            0.70, 0.21, 0.09,
        ])
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Evidence;

    #[test]
    fn all_builtins_load() {
        for name in BUILTIN_NAMES {
            let net = by_name(name).unwrap();
            assert_eq!(net.name(), name);
            assert!(net.n_vars() >= 4);
            assert!(net.topological_order().len() == net.n_vars());
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn asia_shape() {
        let net = asia();
        assert_eq!(net.n_vars(), 8);
        assert_eq!(net.dag().n_edges(), 8);
        // The famous v-structure tub -> either <- lung.
        let either = net.var_index("either").unwrap();
        assert_eq!(net.parents(either).len(), 2);
    }

    #[test]
    fn asia_marginals_match_literature() {
        // Unconditional P(dysp=yes) ≈ 0.436 (Lauritzen & Spiegelhalter).
        let net = asia();
        let dysp = net.var_index("dysp").unwrap();
        let p = net.brute_force_posterior(dysp, &Evidence::new());
        assert!((p[1] - 0.4360).abs() < 1e-3, "P(dysp=yes) = {}", p[1]);
        // P(tub=yes) = 0.99*0.01 + 0.01*0.05 = 0.0104
        let tub = net.var_index("tub").unwrap();
        let p = net.brute_force_posterior(tub, &Evidence::new());
        assert!((p[1] - 0.0104).abs() < 1e-9);
    }

    #[test]
    fn earthquake_alarm_posterior() {
        // P(burglary=yes | john=yes, mary=yes) ≈ 0.284 with these CPTs.
        let net = earthquake();
        let ev = Evidence::new()
            .with(net.var_index("johncalls").unwrap(), 1)
            .with(net.var_index("marycalls").unwrap(), 1);
        let p = net.brute_force_posterior(net.var_index("burglary").unwrap(), &ev);
        assert!((p[1] - 0.284).abs() < 0.01, "got {}", p[1]);
    }

    #[test]
    fn sprinkler_wet_grass() {
        // P(rain=yes | wet=yes) ≈ 0.708 (BNT's classic number).
        let net = sprinkler();
        let ev = Evidence::new().with(net.var_index("wet").unwrap(), 1);
        let p = net.brute_force_posterior(net.var_index("rain").unwrap(), &ev);
        assert!((p[1] - 0.7079).abs() < 1e-3, "got {}", p[1]);
    }

    #[test]
    fn extended_resolver_covers_builtins_and_synthetics() {
        for name in BUILTIN_NAMES {
            assert!(by_name_extended(name).is_some(), "builtin {name}");
        }
        for (name, _) in SYNTHETIC_PRESETS {
            let a = by_name_extended(name).expect(name);
            let b = by_name_extended(name).expect(name);
            // Fixed seed: repeated resolution yields identical parameters.
            assert_eq!(a.n_vars(), b.n_vars());
            for v in 0..a.n_vars() {
                assert_eq!(a.cpt(v).table, b.cpt(v).table, "{name} var {v}");
            }
        }
        assert!(by_name_extended("nope").is_none());
    }

    #[test]
    fn survey_has_ternary() {
        let net = survey();
        assert_eq!(net.cardinality(net.var_index("age").unwrap()), 3);
        assert_eq!(net.cardinality(net.var_index("travel").unwrap()), 3);
        let p = net.brute_force_posterior(net.var_index("travel").unwrap(), &Evidence::new());
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p[0] > p[1] && p[1] > p[2], "car > train > other: {p:?}");
    }
}
