//! Seeded synthetic Bayesian networks.
//!
//! Stand-ins for the non-redistributable bnlearn repository networks the
//! paper's companion evaluations use (CHILD, INSURANCE, ALARM, HEPAR2 …).
//! A [`SyntheticSpec`] fixes node count, in-degree and cardinality ranges;
//! the generator draws a random topologically-ordered DAG and Dirichlet
//! CPTs, all from a seeded [`Pcg`], so every benchmark workload is
//! reproducible from `(preset, seed)`.

use super::{BayesianNetwork, Cpt};
use crate::core::{VarId, Variable};
use crate::graph::Dag;
use crate::rng::Pcg;

/// Parameters of a synthetic network.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub n_nodes: usize,
    /// Maximum number of parents per node.
    pub max_in_degree: usize,
    /// Expected number of parents per (non-root) node.
    pub avg_in_degree: f64,
    /// Cardinalities drawn uniformly from this inclusive range.
    pub card_range: (usize, usize),
    /// Dirichlet concentration for CPT rows (<1 → skewed rows, like real
    /// diagnostic networks).
    pub dirichlet_alpha: f64,
}

impl SyntheticSpec {
    pub fn new(name: impl Into<String>, n_nodes: usize) -> Self {
        SyntheticSpec {
            name: name.into(),
            n_nodes,
            max_in_degree: 4,
            avg_in_degree: 1.8,
            card_range: (2, 4),
            dirichlet_alpha: 0.7,
        }
    }

    /// Scale stand-in for the 20-node CHILD network.
    pub fn child_like() -> Self {
        SyntheticSpec {
            card_range: (2, 6),
            avg_in_degree: 1.25,
            max_in_degree: 3,
            ..SyntheticSpec::new("child_like", 20)
        }
    }

    /// Scale stand-in for the 27-node INSURANCE network.
    pub fn insurance_like() -> Self {
        SyntheticSpec {
            card_range: (2, 5),
            avg_in_degree: 1.9,
            max_in_degree: 3,
            ..SyntheticSpec::new("insurance_like", 27)
        }
    }

    /// Scale stand-in for the 37-node ALARM network.
    pub fn alarm_like() -> Self {
        SyntheticSpec {
            card_range: (2, 4),
            avg_in_degree: 1.24,
            max_in_degree: 4,
            ..SyntheticSpec::new("alarm_like", 37)
        }
    }

    /// Scale stand-in for the 70-node HEPAR2 network. The real HEPAR2 has
    /// high in-degree (up to 6) but a *moderate* treewidth (~11 with
    /// mostly-binary variables); matching its in-degree with random
    /// topology produced treewidth-16 cliques over 4-state variables
    /// (~27M clique states — nothing like the original), so the stand-in
    /// matches node count + induced width instead of raw in-degree.
    pub fn hepar2_like() -> Self {
        SyntheticSpec {
            card_range: (2, 3),
            avg_in_degree: 1.76,
            max_in_degree: 4,
            ..SyntheticSpec::new("hepar2_like", 70)
        }
    }

    /// Scale stand-in for the 76-node WIN95PTS network.
    pub fn win95pts_like() -> Self {
        SyntheticSpec {
            card_range: (2, 2),
            avg_in_degree: 1.47,
            max_in_degree: 7,
            ..SyntheticSpec::new("win95pts_like", 76)
        }
    }

    /// Generate the network.
    pub fn generate(&self, seed: u64) -> BayesianNetwork {
        let mut rng = Pcg::seed_from(seed);
        let n = self.n_nodes;
        // Random topological order = identity (ids are already arbitrary
        // labels); draw parents for node v from {0..v}.
        let variables: Vec<Variable> = (0..n)
            .map(|v| {
                let card = rng.range(self.card_range.0, self.card_range.1 + 1);
                Variable::new(format!("n{v:03}"), card)
            })
            .collect();
        let mut dag = Dag::new(n);
        for v in 1..n {
            let max_here = self.max_in_degree.min(v);
            // Poisson-ish: draw k parents with mean avg_in_degree, capped.
            let mut k = 0;
            let p_more = self.avg_in_degree / (1.0 + self.avg_in_degree);
            while k < max_here && rng.bool_with(p_more) {
                k += 1;
            }
            // Ensure connectivity: every non-root has >= 1 parent with
            // probability 0.9 (real networks have few roots).
            if k == 0 && rng.bool_with(0.9) {
                k = 1;
            }
            for p in rng.choose_k(v, k) {
                dag.add_edge_unchecked(p, v);
            }
        }
        let cpts: Vec<Cpt> = (0..n)
            .map(|v| self.random_cpt(v, &dag, &variables, &mut rng))
            .collect();
        BayesianNetwork::new(
            format!("{}_s{}", self.name, seed),
            variables,
            dag,
            cpts,
        )
    }

    fn random_cpt(
        &self,
        v: VarId,
        dag: &Dag,
        variables: &[Variable],
        rng: &mut Pcg,
    ) -> Cpt {
        let parents = dag.parents(v).to_vec();
        let parent_cards: Vec<usize> =
            parents.iter().map(|&p| variables[p].cardinality).collect();
        let card = variables[v].cardinality;
        let n_cfg: usize = parent_cards.iter().product();
        let mut table = Vec::with_capacity(n_cfg * card);
        for _ in 0..n_cfg {
            table.extend(rng.dirichlet(card, self.dirichlet_alpha));
        }
        Cpt::new(v, parents, parent_cards, card, table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = SyntheticSpec::alarm_like().generate(7);
        let b = SyntheticSpec::alarm_like().generate(7);
        assert_eq!(a.dag().edges(), b.dag().edges());
        assert_eq!(a.cpt(5).table, b.cpt(5).table);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SyntheticSpec::child_like().generate(1);
        let b = SyntheticSpec::child_like().generate(2);
        assert!(a.dag().edges() != b.dag().edges() || a.cpt(3).table != b.cpt(3).table);
    }

    #[test]
    fn respects_spec_bounds() {
        let spec = SyntheticSpec::insurance_like();
        let net = spec.generate(42);
        assert_eq!(net.n_vars(), 27);
        for v in 0..net.n_vars() {
            assert!(net.parents(v).len() <= spec.max_in_degree);
            let c = net.cardinality(v);
            assert!((spec.card_range.0..=spec.card_range.1).contains(&c));
        }
        // Acyclic by construction (BayesianNetwork::new validated it).
        assert_eq!(net.topological_order().len(), 27);
    }

    #[test]
    fn cpts_are_valid_distributions() {
        let net = SyntheticSpec::hepar2_like().generate(3);
        for v in 0..net.n_vars() {
            net.cpt(v).validate(net.variables());
        }
    }

    #[test]
    fn presets_have_paper_scales() {
        assert_eq!(SyntheticSpec::child_like().n_nodes, 20);
        assert_eq!(SyntheticSpec::insurance_like().n_nodes, 27);
        assert_eq!(SyntheticSpec::alarm_like().n_nodes, 37);
        assert_eq!(SyntheticSpec::hepar2_like().n_nodes, 70);
        assert_eq!(SyntheticSpec::win95pts_like().n_nodes, 76);
    }
}
