//! Conditional probability tables.

use crate::core::{Assignment, VarId, Variable};

/// The CPT of one variable: `table[pcfg * card + state] = P(state | pcfg)`.
///
/// Parent configurations are mixed-radix indices over the (sorted) parent
/// list with the **last parent fastest** — the same row-major convention
/// [`crate::potential::PotentialTable`] uses, so family potentials and the
/// AOT artifact layout agree byte-for-byte with this table.
#[derive(Clone, Debug, PartialEq)]
pub struct Cpt {
    /// The child variable.
    pub var: VarId,
    /// Parents, sorted ascending.
    pub parents: Vec<VarId>,
    /// Cardinalities of the parents (aligned with `parents`).
    pub parent_cards: Vec<usize>,
    /// Cardinality of the child.
    pub card: usize,
    /// `n_parent_configs * card` probabilities.
    pub table: Vec<f64>,
}

impl Cpt {
    pub fn new(
        var: VarId,
        parents: Vec<VarId>,
        parent_cards: Vec<usize>,
        card: usize,
        table: Vec<f64>,
    ) -> Self {
        assert_eq!(parents.len(), parent_cards.len());
        assert!(
            parents.windows(2).all(|w| w[0] < w[1]),
            "parents must be sorted: {parents:?}"
        );
        let n_cfg: usize = parent_cards.iter().product();
        assert_eq!(
            table.len(),
            n_cfg * card,
            "CPT for var {var}: expected {} entries, got {}",
            n_cfg * card,
            table.len()
        );
        Cpt { var, parents, parent_cards, card, table }
    }

    /// A root CPT (no parents) from a prior distribution.
    pub fn root(var: VarId, prior: Vec<f64>) -> Self {
        let card = prior.len();
        Cpt::new(var, Vec::new(), Vec::new(), card, prior)
    }

    pub fn n_parent_configs(&self) -> usize {
        self.parent_cards.iter().product()
    }

    /// Check every row is a probability distribution.
    pub fn validate(&self, variables: &[Variable]) {
        assert_eq!(self.card, variables[self.var].cardinality);
        for (k, &p) in self.parents.iter().enumerate() {
            assert_eq!(self.parent_cards[k], variables[p].cardinality);
        }
        for cfg in 0..self.n_parent_configs() {
            let row = &self.table[cfg * self.card..(cfg + 1) * self.card];
            assert!(
                row.iter().all(|&p| (0.0..=1.0 + 1e-9).contains(&p)),
                "CPT row out of range for var {}: {row:?}",
                self.var
            );
            let s: f64 = row.iter().sum();
            assert!(
                (s - 1.0).abs() < 1e-6,
                "CPT row for var {} cfg {cfg} sums to {s}",
                self.var
            );
        }
    }

    /// Mixed-radix parent-configuration index, reading parent states via a
    /// callback (`k` = position in the parent list).
    #[inline]
    pub fn parent_config_from(&self, state_of: impl Fn(usize) -> usize) -> usize {
        let mut cfg = 0;
        for k in 0..self.parents.len() {
            cfg = cfg * self.parent_cards[k] + state_of(k);
        }
        cfg
    }

    /// Parent-configuration index under a full assignment.
    #[inline]
    pub fn parent_config(&self, a: &Assignment) -> usize {
        self.parent_config_from(|k| a.get(self.parents[k]))
    }

    /// P(state | cfg).
    #[inline]
    pub fn prob(&self, cfg: usize, state: usize) -> f64 {
        self.table[cfg * self.card + state]
    }

    /// The distribution row for a configuration.
    #[inline]
    pub fn row(&self, cfg: usize) -> &[f64] {
        &self.table[cfg * self.card..(cfg + 1) * self.card]
    }

    /// P(state | parents as assigned in `a`).
    #[inline]
    pub fn prob_given(&self, state: usize, a: &Assignment) -> f64 {
        self.prob(self.parent_config(a), state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn root_cpt() {
        let c = Cpt::root(0, vec![0.25, 0.75]);
        assert_eq!(c.n_parent_configs(), 1);
        assert_eq!(c.prob(0, 1), 0.75);
        assert_eq!(c.row(0), &[0.25, 0.75]);
    }

    #[test]
    fn parent_config_last_fastest() {
        // parents (1, 2) with cards (2, 3): cfg = s1 * 3 + s2
        let table: Vec<f64> = (0..6).flat_map(|_| [0.4, 0.6]).collect();
        let c = Cpt::new(3, vec![1, 2], vec![2, 3], 2, table);
        let mut a = Assignment::zeros(4);
        a.set(1, 1);
        a.set(2, 2);
        assert_eq!(c.parent_config(&a), 5);
        assert_eq!(c.prob_given(1, &a), 0.6);
    }

    #[test]
    #[should_panic]
    fn wrong_size_table_rejected() {
        let _ = Cpt::new(0, vec![], vec![], 2, vec![1.0]);
    }

    #[test]
    #[should_panic]
    fn non_distribution_row_fails_validate() {
        let c = Cpt::root(0, vec![0.5, 0.2]);
        c.validate(&[Variable::binary("x")]);
    }
}
