//! Bayesian networks: structure (a [`Dag`]) plus one conditional
//! probability table per variable.

mod cpt;
pub mod repository;
pub mod synthetic;

pub use cpt::Cpt;

use crate::core::{Assignment, Evidence, VarId, Variable};
use crate::graph::Dag;
use crate::potential::PotentialTable;

/// A discrete Bayesian network.
///
/// Invariants (enforced by [`BayesianNetwork::new`] and the builder):
/// * the graph is acyclic;
/// * `cpts[v].var == v`, its parent list equals `dag.parents(v)` (sorted);
/// * every CPT row is a distribution (non-negative, sums to 1 within 1e-6).
#[derive(Clone, Debug)]
pub struct BayesianNetwork {
    name: String,
    variables: Vec<Variable>,
    dag: Dag,
    cpts: Vec<Cpt>,
    /// Cached topological order.
    topo: Vec<VarId>,
}

impl BayesianNetwork {
    /// Assemble and validate a network.
    pub fn new(
        name: impl Into<String>,
        variables: Vec<Variable>,
        dag: Dag,
        cpts: Vec<Cpt>,
    ) -> Self {
        let n = variables.len();
        assert_eq!(dag.n_nodes(), n, "graph / variable count mismatch");
        assert_eq!(cpts.len(), n, "need one CPT per variable");
        let topo = dag
            .topological_order()
            .expect("Bayesian network structure must be acyclic");
        for (v, cpt) in cpts.iter().enumerate() {
            assert_eq!(cpt.var, v, "CPT {v} attached to wrong variable");
            assert_eq!(
                cpt.parents,
                dag.parents(v),
                "CPT parent set for {} disagrees with the graph",
                variables[v].name
            );
            cpt.validate(&variables);
        }
        BayesianNetwork { name: name.into(), variables, dag, cpts, topo }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn n_vars(&self) -> usize {
        self.variables.len()
    }

    /// Total number of independent parameters (CPT entries minus one per
    /// row) — the "size" figure papers quote for networks.
    pub fn n_parameters(&self) -> usize {
        self.cpts
            .iter()
            .enumerate()
            .map(|(v, c)| c.n_parent_configs() * (self.variables[v].cardinality - 1))
            .sum()
    }

    pub fn variables(&self) -> &[Variable] {
        &self.variables
    }

    pub fn variable(&self, v: VarId) -> &Variable {
        &self.variables[v]
    }

    pub fn cardinality(&self, v: VarId) -> usize {
        self.variables[v].cardinality
    }

    pub fn var_index(&self, name: &str) -> Option<VarId> {
        self.variables.iter().position(|v| v.name == name)
    }

    pub fn dag(&self) -> &Dag {
        &self.dag
    }

    pub fn parents(&self, v: VarId) -> &[VarId] {
        self.dag.parents(v)
    }

    pub fn cpt(&self, v: VarId) -> &Cpt {
        &self.cpts[v]
    }

    pub fn cpts(&self) -> &[Cpt] {
        &self.cpts
    }

    /// Topological order (cached at construction).
    pub fn topological_order(&self) -> &[VarId] {
        &self.topo
    }

    /// P(var = state | parents as in `a`).
    #[inline]
    pub fn prob(&self, v: VarId, state: usize, a: &Assignment) -> f64 {
        self.cpts[v].prob_given(state, a)
    }

    /// Joint probability of a complete assignment.
    pub fn joint_prob(&self, a: &Assignment) -> f64 {
        self.cpts
            .iter()
            .map(|c| c.prob_given(a.get(c.var), a))
            .product()
    }

    /// Joint log-probability of a complete assignment (the quantity the
    /// AOT-compiled batch scorer computes for evidence batches).
    pub fn joint_log_prob(&self, a: &Assignment) -> f64 {
        self.cpts
            .iter()
            .map(|c| c.prob_given(a.get(c.var), a).max(f64::MIN_POSITIVE).ln())
            .sum()
    }

    /// The family factor P(v | parents) as a canonical potential table over
    /// `{v} ∪ parents(v)` — the starting point of both junction-tree and
    /// variable-elimination inference.
    pub fn family_potential(&self, v: VarId) -> PotentialTable {
        let cpt = &self.cpts[v];
        let mut scope: Vec<VarId> = cpt.parents.clone();
        scope.push(v);
        scope.sort_unstable();
        let scope_cards: Vec<usize> =
            scope.iter().map(|&u| self.cardinality(u)).collect();
        let mut table = PotentialTable::zeros(scope.clone(), scope_cards.clone());
        let pos_of = |u: VarId| scope.binary_search(&u).unwrap();
        let v_pos = pos_of(v);
        let parent_pos: Vec<usize> =
            cpt.parents.iter().map(|&p| pos_of(p)).collect();
        let mut digits = vec![0usize; scope.len()];
        for i in 0..table.len() {
            let state = digits[v_pos];
            let pcfg = cpt.parent_config_from(|k| digits[parent_pos[k]]);
            table.data_mut()[i] = cpt.prob(pcfg, state);
            PotentialTable::advance(&mut digits, &scope_cards);
        }
        table
    }

    /// Brute-force exact posterior P(v | evidence) by enumerating the full
    /// joint — exponential, only viable for tiny nets; the ground-truth
    /// oracle the test suite checks every inference engine against.
    pub fn brute_force_posterior(&self, v: VarId, ev: &Evidence) -> Vec<f64> {
        let n = self.n_vars();
        let card = self.cardinality(v);
        let mut post = vec![0.0; card];
        let cards: Vec<usize> = (0..n).map(|u| self.cardinality(u)).collect();
        let mut a = Assignment::zeros(n);
        let total: usize = cards.iter().product();
        let mut digits = vec![0usize; n];
        for _ in 0..total {
            for (u, &d) in digits.iter().enumerate() {
                a.set(u, d);
            }
            if ev.consistent_with(&a) {
                post[a.get(v)] += net_joint(self, &a);
            }
            PotentialTable::advance(&mut digits, &cards);
        }
        let s: f64 = post.iter().sum();
        if s > 0.0 {
            for p in &mut post {
                *p /= s;
            }
        }
        post
    }
}

#[inline]
fn net_joint(net: &BayesianNetwork, a: &Assignment) -> f64 {
    net.joint_prob(a)
}

/// Incremental construction of a [`BayesianNetwork`].
#[derive(Default)]
pub struct NetworkBuilder {
    name: String,
    variables: Vec<Variable>,
    edges: Vec<(String, String)>,
    cpts: Vec<(String, Vec<f64>)>,
}

impl NetworkBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        NetworkBuilder { name: name.into(), ..Default::default() }
    }

    pub fn variable(mut self, v: Variable) -> Self {
        assert!(
            !self.variables.iter().any(|w| w.name == v.name),
            "duplicate variable {}",
            v.name
        );
        self.variables.push(v);
        self
    }

    pub fn edge(mut self, from: &str, to: &str) -> Self {
        self.edges.push((from.into(), to.into()));
        self
    }

    /// Provide the CPT for `var` as rows over parent configurations
    /// (parents in *sorted VarId order*, last parent fastest), each row
    /// listing P(state | config).
    pub fn cpt(mut self, var: &str, table: Vec<f64>) -> Self {
        self.cpts.push((var.into(), table));
        self
    }

    pub fn build(self) -> BayesianNetwork {
        let index = |name: &str| -> VarId {
            self.variables
                .iter()
                .position(|v| v.name == name)
                .unwrap_or_else(|| panic!("unknown variable {name}"))
        };
        let mut dag = Dag::new(self.variables.len());
        for (f, t) in &self.edges {
            dag.add_edge(index(f), index(t));
        }
        let mut cpts: Vec<Option<Cpt>> = vec![None; self.variables.len()];
        for (name, data) in self.cpts {
            let v = index(&name);
            let parents = dag.parents(v).to_vec();
            let parent_cards: Vec<usize> =
                parents.iter().map(|&p| self.variables[p].cardinality).collect();
            cpts[v] = Some(Cpt::new(
                v,
                parents,
                parent_cards,
                self.variables[v].cardinality,
                data,
            ));
        }
        let cpts: Vec<Cpt> = cpts
            .into_iter()
            .enumerate()
            .map(|(v, c)| {
                c.unwrap_or_else(|| panic!("missing CPT for {}", self.variables[v].name))
            })
            .collect();
        BayesianNetwork::new(self.name, self.variables, dag, cpts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_node() -> BayesianNetwork {
        NetworkBuilder::new("two")
            .variable(Variable::binary("a"))
            .variable(Variable::binary("b"))
            .edge("a", "b")
            .cpt("a", vec![0.7, 0.3])
            .cpt("b", vec![0.9, 0.1, 0.2, 0.8])
            .build()
    }

    #[test]
    fn builder_assembles() {
        let net = two_node();
        assert_eq!(net.n_vars(), 2);
        assert_eq!(net.parents(1), &[0]);
        assert_eq!(net.n_parameters(), 1 + 2);
        assert_eq!(net.topological_order(), &[0, 1]);
    }

    #[test]
    fn joint_prob_factorizes() {
        let net = two_node();
        let mut a = Assignment::zeros(2);
        a.set(0, 1);
        a.set(1, 1);
        assert!((net.joint_prob(&a) - 0.3 * 0.8).abs() < 1e-12);
        assert!((net.joint_log_prob(&a) - (0.3f64 * 0.8).ln()).abs() < 1e-12);
    }

    #[test]
    fn family_potential_matches_cpt() {
        let net = two_node();
        let f = net.family_potential(1);
        assert_eq!(f.vars(), &[0, 1]);
        assert!((f.value_at(&[0, 0]) - 0.9).abs() < 1e-12);
        assert!((f.value_at(&[1, 1]) - 0.8).abs() < 1e-12);
        assert!((f.value_at(&[1, 0]) + f.value_at(&[1, 1]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn brute_force_posterior_bayes_rule() {
        let net = two_node();
        // P(a=1 | b=1) = 0.3*0.8 / (0.7*0.1 + 0.3*0.8)
        let ev = Evidence::new().with(1, 1);
        let post = net.brute_force_posterior(0, &ev);
        let expect = 0.24 / (0.07 + 0.24);
        assert!((post[1] - expect).abs() < 1e-12);
        assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn missing_cpt_panics() {
        let _ = NetworkBuilder::new("bad")
            .variable(Variable::binary("a"))
            .build();
    }
}
