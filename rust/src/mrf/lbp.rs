//! Loopy belief propagation on general factor graphs (the MRF analogue
//! of [`crate::inference::approx::LoopyBp`], which is specialized to
//! Bayesian-network families).

use crate::core::{Evidence, VarId};
use crate::inference::normalize_in_place;
use crate::parallel::parallel_map;
use crate::potential::PotentialTable;
use super::FactorGraph;

/// LBP options for factor graphs.
#[derive(Clone, Debug)]
pub struct MrfLbpOptions {
    pub max_iters: usize,
    pub tolerance: f64,
    pub damping: f64,
    pub threads: usize,
}

impl Default for MrfLbpOptions {
    fn default() -> Self {
        MrfLbpOptions { max_iters: 100, tolerance: 1e-6, damping: 0.3, threads: 1 }
    }
}

/// Result of a factor-graph LBP run.
#[derive(Clone, Debug)]
pub struct MrfLbpResult {
    /// Per-variable beliefs (normalized).
    pub beliefs: Vec<Vec<f64>>,
    pub iterations: usize,
    pub converged: bool,
}

impl MrfLbpResult {
    /// MAP-ish decoding: argmax belief per variable.
    pub fn decode(&self) -> Vec<usize> {
        self.beliefs.iter().map(|b| crate::classify::argmax(b)).collect()
    }
}

/// Run sum-product LBP on a (possibly evidence-conditioned) factor graph.
pub fn run_lbp(fg: &FactorGraph, evidence: &Evidence, opts: &MrfLbpOptions) -> MrfLbpResult {
    let fg = if evidence.is_empty() {
        fg.clone()
    } else {
        fg.condition(evidence)
    };
    let n = fg.n_vars();
    let factors = fg.factors();

    let mut var_factors: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for (fi, f) in factors.iter().enumerate() {
        for (pos, &v) in f.vars().iter().enumerate() {
            var_factors[v].push((fi, pos));
        }
    }

    let msg_init = |fi: usize, pos: usize| {
        let card = factors[fi].cards()[pos];
        vec![1.0 / card as f64; card]
    };
    let mut f2v: Vec<Vec<Vec<f64>>> = factors
        .iter()
        .enumerate()
        .map(|(fi, f)| (0..f.vars().len()).map(|p| msg_init(fi, p)).collect())
        .collect();
    let mut v2f = f2v.clone();

    let mut iterations = 0;
    let mut converged = false;
    while iterations < opts.max_iters {
        iterations += 1;
        let new_f2v: Vec<Vec<Vec<f64>>> =
            parallel_map(factors.len(), opts.threads, 8, |fi| {
                let f = &factors[fi];
                let k = f.vars().len();
                let mut out: Vec<Vec<f64>> =
                    (0..k).map(|p| vec![0.0; f.cards()[p]]).collect();
                let mut digits = vec![0usize; k];
                for idx in 0..f.len() {
                    let base = f.data()[idx];
                    if base != 0.0 {
                        let mut full = base;
                        for (pos, d) in digits.iter().enumerate() {
                            full *= v2f[fi][pos][*d];
                        }
                        if full != 0.0 {
                            for (pos, d) in digits.iter().enumerate() {
                                let inc = v2f[fi][pos][*d];
                                if inc > 0.0 {
                                    out[pos][*d] += full / inc;
                                }
                            }
                        } else {
                            for pos in 0..k {
                                let mut loo = base;
                                for (p2, d2) in digits.iter().enumerate() {
                                    if p2 != pos {
                                        loo *= v2f[fi][p2][*d2];
                                    }
                                }
                                out[pos][digits[pos]] += loo;
                            }
                        }
                    }
                    PotentialTable::advance(&mut digits, f.cards());
                }
                for m in &mut out {
                    normalize_in_place(m);
                }
                out
            });
        let mut max_delta = 0.0f64;
        for fi in 0..factors.len() {
            for pos in 0..f2v[fi].len() {
                for s in 0..f2v[fi][pos].len() {
                    let nv = opts.damping * f2v[fi][pos][s]
                        + (1.0 - opts.damping) * new_f2v[fi][pos][s];
                    max_delta = max_delta.max((nv - f2v[fi][pos][s]).abs());
                    f2v[fi][pos][s] = nv;
                }
            }
        }
        for v in 0..n {
            for &(fi, pos) in &var_factors[v] {
                let card = factors[fi].cards()[pos];
                let mut m = vec![1.0f64; card];
                for &(gi, gpos) in &var_factors[v] {
                    if gi == fi && gpos == pos {
                        continue;
                    }
                    for s in 0..card {
                        m[s] *= f2v[gi][gpos][s];
                    }
                }
                normalize_in_place(&mut m);
                v2f[fi][pos] = m;
            }
        }
        if max_delta < opts.tolerance {
            converged = true;
            break;
        }
    }

    let beliefs: Vec<Vec<f64>> = (0..n)
        .map(|v| {
            let card = fg.cardinality(v);
            let mut b = vec![1.0f64; card];
            for &(fi, pos) in &var_factors[v] {
                for s in 0..card {
                    b[s] *= f2v[fi][pos][s];
                }
            }
            normalize_in_place(&mut b);
            if b.iter().sum::<f64>() == 0.0 {
                b = vec![1.0 / card as f64; card];
            }
            b
        })
        .collect();
    MrfLbpResult { beliefs, iterations, converged }
}

/// Convenience: beliefs of one variable.
pub fn marginal(fg: &FactorGraph, v: VarId, ev: &Evidence, opts: &MrfLbpOptions) -> Vec<f64> {
    run_lbp(fg, ev, opts).beliefs.swap_remove(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close_dist;

    #[test]
    fn exact_on_tree_mrf() {
        // A 1×4 chain is a tree: LBP is exact.
        let fg = FactorGraph::grid(1, 4, 2, 0.8, |_, c| {
            if c == 0 { vec![3.0, 1.0] } else { vec![1.0, 1.0] }
        });
        let r = run_lbp(&fg, &Evidence::new(), &MrfLbpOptions::default());
        assert!(r.converged);
        for v in 0..4 {
            let want = fg.brute_force_marginal(v, &Evidence::new());
            assert_close_dist(&r.beliefs[v], &want, 1e-6, &format!("var {v}"));
        }
    }

    #[test]
    fn close_on_small_loopy_grid() {
        let fg = FactorGraph::grid(3, 3, 2, 0.5, |r, c| {
            if (r + c) % 2 == 0 { vec![2.0, 1.0] } else { vec![1.0, 1.5] }
        });
        let r = run_lbp(&fg, &Evidence::new(), &MrfLbpOptions::default());
        for v in 0..9 {
            let want = fg.brute_force_marginal(v, &Evidence::new());
            assert_close_dist(&r.beliefs[v], &want, 0.05, &format!("var {v}"));
        }
    }

    #[test]
    fn evidence_conditioning() {
        let fg = FactorGraph::grid(2, 2, 2, 1.0, |_, _| vec![1.0, 1.0]);
        let ev = Evidence::new().with(0, 1);
        let r = run_lbp(&fg, &ev, &MrfLbpOptions::default());
        // Strong coupling pulls neighbors toward state 1.
        assert!(r.beliefs[1][1] > 0.6);
        assert!(r.beliefs[2][1] > 0.6);
        let want = fg.brute_force_marginal(3, &ev);
        assert_close_dist(&r.beliefs[3], &want, 0.05, "var 3");
    }

    #[test]
    fn matches_bn_lbp_on_converted_network() {
        let net = crate::network::repository::cancer();
        let fg = FactorGraph::from_bayesian_network(&net);
        let ev = Evidence::new().with(3, 1);
        let r = run_lbp(&fg, &ev, &MrfLbpOptions::default());
        for v in 0..net.n_vars() {
            if ev.contains(v) {
                continue;
            }
            let want = net.brute_force_posterior(v, &ev);
            assert_close_dist(&r.beliefs[v], &want, 1e-4, &format!("var {v}"));
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let fg = FactorGraph::grid(4, 4, 2, 0.4, |r, c| {
            vec![1.0 + r as f64 * 0.1, 1.0 + c as f64 * 0.1]
        });
        let a = run_lbp(&fg, &Evidence::new(), &MrfLbpOptions { threads: 1, ..Default::default() });
        let b = run_lbp(&fg, &Evidence::new(), &MrfLbpOptions { threads: 4, ..Default::default() });
        for (x, y) in a.beliefs.iter().zip(&b.beliefs) {
            assert_close_dist(x, y, 1e-12, "thread invariance");
        }
    }
}
