//! Gibbs sampling on factor graphs.

use crate::core::{Assignment, Evidence, VarId};
use crate::parallel::parallel_map;
use crate::rng::Pcg;
use super::FactorGraph;

/// Options for MRF Gibbs sampling.
#[derive(Clone, Debug)]
pub struct MrfGibbsOptions {
    /// Recorded sweeps (after burn-in), across all chains.
    pub sweeps: usize,
    pub burn_in: usize,
    pub chains: usize,
    pub threads: usize,
    pub seed: u64,
}

impl Default for MrfGibbsOptions {
    fn default() -> Self {
        MrfGibbsOptions { sweeps: 2_000, burn_in: 200, chains: 4, threads: 1, seed: 0xFACE }
    }
}

/// Per-variable marginal estimates from Gibbs sweeps.
pub fn gibbs_marginals(
    fg: &FactorGraph,
    evidence: &Evidence,
    opts: &MrfGibbsOptions,
) -> Vec<Vec<f64>> {
    let n = fg.n_vars();
    // Factors touching each variable, with the variable's position.
    let mut var_factors: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for (fi, f) in fg.factors().iter().enumerate() {
        for (pos, &v) in f.vars().iter().enumerate() {
            var_factors[v].push((fi, pos));
        }
    }
    let unobserved: Vec<VarId> =
        (0..n).filter(|&v| !evidence.contains(v)).collect();
    let chains = opts.chains.max(1);
    let per_chain = opts.sweeps.div_ceil(chains);
    let mut root = Pcg::seed_from(opts.seed);
    let seeds: Vec<Pcg> = (0..chains).map(|c| root.split(c as u64)).collect();

    let partials: Vec<Vec<Vec<f64>>> = parallel_map(chains, opts.threads, 1, |c| {
        let mut rng = seeds[c].clone();
        let mut counts: Vec<Vec<f64>> =
            (0..n).map(|v| vec![0.0; fg.cardinality(v)]).collect();
        // Random legal init, evidence clamped.
        let mut a = Assignment::zeros(n);
        for v in 0..n {
            a.set(v, rng.below(fg.cardinality(v)));
        }
        evidence.apply_to(&mut a);
        let mut cond = Vec::new();
        for sweep in 0..(opts.burn_in + per_chain) {
            for &v in &unobserved {
                let card = fg.cardinality(v);
                cond.clear();
                cond.resize(card, 1.0);
                for &(fi, _pos) in &var_factors[v] {
                    let f = &fg.factors()[fi];
                    for (s, value) in cond.iter_mut().enumerate() {
                        a.set(v, s);
                        let digits: Vec<usize> =
                            f.vars().iter().map(|&u| a.get(u)).collect();
                        *value *= f.value_at(&digits);
                    }
                }
                let total: f64 = cond.iter().sum();
                let s = if total > 0.0 {
                    let mut u = rng.next_f64() * total;
                    let mut pick = card - 1;
                    for (i, &w) in cond.iter().enumerate() {
                        u -= w;
                        if u < 0.0 {
                            pick = i;
                            break;
                        }
                    }
                    pick
                } else {
                    rng.below(card)
                };
                a.set(v, s);
            }
            if sweep >= opts.burn_in {
                for v in 0..n {
                    counts[v][a.get(v)] += 1.0;
                }
            }
        }
        counts
    });

    let mut totals: Vec<Vec<f64>> =
        (0..n).map(|v| vec![0.0; fg.cardinality(v)]).collect();
    for part in &partials {
        for (t, p) in totals.iter_mut().zip(part) {
            for (x, y) in t.iter_mut().zip(p) {
                *x += y;
            }
        }
    }
    for (v, t) in totals.iter_mut().enumerate() {
        let s: f64 = t.iter().sum();
        if s > 0.0 {
            for x in t.iter_mut() {
                *x /= s;
            }
        } else if let Some(obs) = evidence.get(v) {
            t[obs] = 1.0;
        }
    }
    // Point masses for evidence.
    for (v, s) in evidence.iter() {
        let mut p = vec![0.0; fg.cardinality(v)];
        p[s] = 1.0;
        totals[v] = p;
    }
    totals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close_dist;

    #[test]
    fn converges_on_small_grid() {
        let fg = FactorGraph::grid(2, 3, 2, 0.6, |r, c| {
            if (r + c) % 2 == 0 { vec![2.0, 1.0] } else { vec![1.0, 2.0] }
        });
        let opts = MrfGibbsOptions { sweeps: 30_000, ..Default::default() };
        let got = gibbs_marginals(&fg, &Evidence::new(), &opts);
        for v in 0..fg.n_vars() {
            let want = fg.brute_force_marginal(v, &Evidence::new());
            assert_close_dist(&got[v], &want, 0.03, &format!("var {v}"));
        }
    }

    #[test]
    fn respects_evidence() {
        let fg = FactorGraph::grid(2, 2, 2, 0.8, |_, _| vec![1.0, 1.0]);
        let ev = Evidence::new().with(0, 1);
        let got = gibbs_marginals(&fg, &ev, &MrfGibbsOptions::default());
        assert_eq!(got[0], vec![0.0, 1.0]);
        assert!(got[1][1] > 0.6, "coupling pulls neighbor: {:?}", got[1]);
    }

    #[test]
    fn deterministic_given_seed() {
        let fg = FactorGraph::grid(2, 2, 3, 0.3, |_, _| vec![1.0, 2.0, 1.0]);
        let opts = MrfGibbsOptions { sweeps: 1_000, ..Default::default() };
        let a = gibbs_marginals(&fg, &Evidence::new(), &opts);
        let b = gibbs_marginals(
            &fg,
            &Evidence::new(),
            &MrfGibbsOptions { threads: 2, ..opts },
        );
        assert_eq!(a, b);
    }
}
