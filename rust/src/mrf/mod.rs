//! Markov random fields / factor graphs.
//!
//! The paper positions Fast-PGM as a *PGM* library and motivates it with
//! Markov-network applications (vision, protein interaction). This module
//! supplies the undirected side: a [`FactorGraph`] over discrete
//! variables with arbitrary potential-table factors, builders for the
//! common cases (pairwise grids, conversion from a Bayesian network), and
//! approximate inference via loopy BP ([`lbp`]) and Gibbs sampling
//! ([`gibbs`]).

pub mod gibbs;
pub mod lbp;

use crate::core::{Assignment, Evidence, VarId, Variable};
use crate::network::BayesianNetwork;
use crate::potential::ops::IndexMode;
use crate::potential::PotentialTable;

/// A discrete factor graph: variables + non-negative factors over subsets.
#[derive(Clone, Debug)]
pub struct FactorGraph {
    variables: Vec<Variable>,
    factors: Vec<PotentialTable>,
}

impl FactorGraph {
    pub fn new(variables: Vec<Variable>) -> Self {
        FactorGraph { variables, factors: Vec::new() }
    }

    /// Add a factor; its scope must reference declared variables with
    /// matching cardinalities.
    pub fn add_factor(&mut self, factor: PotentialTable) {
        for (&v, &c) in factor.vars().iter().zip(factor.cards()) {
            assert!(v < self.variables.len(), "factor scope out of range");
            assert_eq!(
                c, self.variables[v].cardinality,
                "cardinality mismatch for variable {v}"
            );
        }
        assert!(factor.data().iter().all(|&x| x >= 0.0), "negative potential");
        self.factors.push(factor);
    }

    pub fn n_vars(&self) -> usize {
        self.variables.len()
    }

    pub fn variables(&self) -> &[Variable] {
        &self.variables
    }

    pub fn cardinality(&self, v: VarId) -> usize {
        self.variables[v].cardinality
    }

    pub fn factors(&self) -> &[PotentialTable] {
        &self.factors
    }

    /// Unnormalized measure of a complete assignment.
    pub fn unnormalized_prob(&self, a: &Assignment) -> f64 {
        self.factors
            .iter()
            .map(|f| {
                let digits: Vec<usize> =
                    f.vars().iter().map(|&v| a.get(v)).collect();
                f.value_at(&digits)
            })
            .product()
    }

    /// Exact partition function by enumeration (tiny graphs only — the
    /// test oracle).
    pub fn partition_function(&self) -> f64 {
        let cards: Vec<usize> =
            self.variables.iter().map(|v| v.cardinality).collect();
        let total: usize = cards.iter().product();
        let mut digits = vec![0usize; cards.len()];
        let mut z = 0.0;
        let mut a = Assignment::zeros(cards.len());
        for _ in 0..total {
            for (v, &d) in digits.iter().enumerate() {
                a.set(v, d);
            }
            z += self.unnormalized_prob(&a);
            PotentialTable::advance(&mut digits, &cards);
        }
        z
    }

    /// Exact marginal by enumeration (test oracle).
    pub fn brute_force_marginal(&self, v: VarId, ev: &Evidence) -> Vec<f64> {
        let cards: Vec<usize> =
            self.variables.iter().map(|x| x.cardinality).collect();
        let total: usize = cards.iter().product();
        let mut digits = vec![0usize; cards.len()];
        let mut post = vec![0.0; self.cardinality(v)];
        let mut a = Assignment::zeros(cards.len());
        for _ in 0..total {
            for (u, &d) in digits.iter().enumerate() {
                a.set(u, d);
            }
            if ev.consistent_with(&a) {
                post[a.get(v)] += self.unnormalized_prob(&a);
            }
            PotentialTable::advance(&mut digits, &cards);
        }
        let s: f64 = post.iter().sum();
        if s > 0.0 {
            for p in &mut post {
                *p /= s;
            }
        }
        post
    }

    /// Convert a Bayesian network into its factor-graph representation
    /// (one factor per family; the joint is identical).
    pub fn from_bayesian_network(net: &BayesianNetwork) -> Self {
        let mut fg = FactorGraph::new(net.variables().to_vec());
        for v in 0..net.n_vars() {
            fg.add_factor(net.family_potential(v));
        }
        fg
    }

    /// Pairwise 4-connected grid MRF (the vision workhorse): `rows × cols`
    /// variables with `states` states each, one unary factor per node from
    /// `unary(r, c)` and one Potts-style pairwise factor per edge:
    /// `exp(coupling)` on the diagonal, 1 off it.
    pub fn grid(
        rows: usize,
        cols: usize,
        states: usize,
        coupling: f64,
        mut unary: impl FnMut(usize, usize) -> Vec<f64>,
    ) -> Self {
        let variables: Vec<Variable> = (0..rows * cols)
            .map(|i| Variable::new(format!("x{}_{}", i / cols, i % cols), states))
            .collect();
        let mut fg = FactorGraph::new(variables);
        let id = |r: usize, c: usize| r * cols + c;
        for r in 0..rows {
            for c in 0..cols {
                let u = unary(r, c);
                assert_eq!(u.len(), states);
                fg.add_factor(PotentialTable::from_data(
                    vec![id(r, c)],
                    vec![states],
                    u,
                ));
            }
        }
        let same = coupling.exp();
        let mut pairwise = vec![1.0; states * states];
        for s in 0..states {
            pairwise[s * states + s] = same;
        }
        for r in 0..rows {
            for c in 0..cols {
                if c + 1 < cols {
                    let (a, b) = (id(r, c), id(r, c + 1));
                    fg.add_factor(PotentialTable::from_data(
                        vec![a.min(b), a.max(b)],
                        vec![states, states],
                        pairwise.clone(),
                    ));
                }
                if r + 1 < rows {
                    let (a, b) = (id(r, c), id(r + 1, c));
                    fg.add_factor(PotentialTable::from_data(
                        vec![a.min(b), a.max(b)],
                        vec![states, states],
                        pairwise.clone(),
                    ));
                }
            }
        }
        fg
    }

    /// Absorb evidence by reducing every factor (returns a new graph).
    pub fn condition(&self, ev: &Evidence) -> FactorGraph {
        let mut fg = FactorGraph::new(self.variables.clone());
        for f in &self.factors {
            let mut g = f.clone();
            g.reduce_evidence(ev);
            fg.factors.push(g);
        }
        fg
    }

    /// Pointwise product of all factors marginalized to `v` — exact only
    /// for trivial graphs; kept for diagnostics.
    pub fn naive_marginal(&self, v: VarId) -> Vec<f64> {
        let mut joint = PotentialTable::scalar(1.0);
        for f in &self.factors {
            joint = joint.product(f, IndexMode::Odometer);
        }
        let m = joint.marginalize_keep(&[v], IndexMode::Odometer);
        let mut p = m.data().to_vec();
        let s: f64 = p.iter().sum();
        if s > 0.0 {
            for x in &mut p {
                *x /= s;
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;

    #[test]
    fn from_bn_preserves_joint() {
        let net = repository::cancer();
        let fg = FactorGraph::from_bayesian_network(&net);
        let mut a = Assignment::zeros(net.n_vars());
        a.set(0, 1);
        a.set(2, 1);
        assert!((fg.unnormalized_prob(&a) - net.joint_prob(&a)).abs() < 1e-12);
        assert!((fg.partition_function() - 1.0).abs() < 1e-9, "BN sums to 1");
    }

    #[test]
    fn grid_construction() {
        let fg = FactorGraph::grid(3, 4, 2, 0.5, |_, _| vec![1.0, 1.0]);
        assert_eq!(fg.n_vars(), 12);
        // 12 unary + 3*3 + 2*4 pairwise = 12 + 17.
        assert_eq!(fg.factors().len(), 12 + 17);
    }

    #[test]
    fn grid_coupling_favors_agreement() {
        let fg = FactorGraph::grid(1, 2, 2, 1.0, |_, _| vec![1.0, 1.0]);
        let mut same = Assignment::zeros(2);
        let mut diff = Assignment::zeros(2);
        diff.set(1, 1);
        assert!(fg.unnormalized_prob(&same) > fg.unnormalized_prob(&diff));
        let _ = &mut same;
    }

    #[test]
    fn brute_marginal_normalized() {
        let fg = FactorGraph::grid(2, 2, 2, 0.7, |r, c| {
            if (r + c) % 2 == 0 { vec![2.0, 1.0] } else { vec![1.0, 2.0] }
        });
        let m = fg.brute_force_marginal(0, &Evidence::new());
        assert!((m.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(m[0] > 0.5, "unary prior pulls state 0: {m:?}");
    }

    #[test]
    #[should_panic]
    fn mismatched_cardinality_rejected() {
        let mut fg = FactorGraph::new(vec![Variable::new("a", 2)]);
        fg.add_factor(PotentialTable::unit(vec![0], vec![3]));
    }
}
