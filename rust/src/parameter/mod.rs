//! Parameter learning: maximum-likelihood estimation of CPTs given a
//! structure, with Laplace smoothing and sufficient statistics drawn
//! from the shared counting substrate (paper §2 + optimization (ii)):
//! family tables come from [`crate::counts::CountCache`], so repeated
//! families hit, subsets of already-counted tables (e.g. CI-test joints
//! from a preceding PC run over the same cache) project instead of
//! rescanning rows, and the derived counts are bit-identical to direct
//! counting ([`count_family`] stays as the direct-path oracle).

use crate::core::{Dataset, VarId};
use crate::counts::CountCache;
use crate::graph::Dag;
use crate::network::{BayesianNetwork, Cpt};
use crate::parallel::parallel_map;

/// Options for MLE.
#[derive(Clone, Debug)]
pub struct MleOptions {
    /// Laplace/Dirichlet pseudo-count added to every cell (0 = pure MLE;
    /// rows with zero observations then fall back to uniform).
    pub pseudo_count: f64,
    /// Worker threads (families are counted independently).
    pub threads: usize,
}

impl Default for MleOptions {
    fn default() -> Self {
        MleOptions { pseudo_count: 1.0, threads: 1 }
    }
}

/// Sufficient statistics for one family: counts over
/// `(parent configuration, child state)`.
#[derive(Clone, Debug, Default)]
pub struct FamilyCounts {
    pub var: VarId,
    pub counts: Vec<u64>,
    pub card: usize,
}

/// Count one family's sufficient statistics in a single column-major pass:
/// the child and parent columns are each contiguous, so the scan touches
/// `(1 + #parents)` dense arrays sequentially (optimization ii). This is
/// the direct-path oracle the substrate-backed
/// [`family_counts_cached`] is equivalence-tested against.
pub fn count_family(data: &Dataset, var: VarId, parents: &[VarId]) -> FamilyCounts {
    let card = data.cardinality(var);
    let parent_cards: Vec<usize> =
        parents.iter().map(|&p| data.cardinality(p)).collect();
    let n_cfg: usize = parent_cards.iter().product();
    let mut counts = vec![0u64; n_cfg * card];
    let col_v = data.column(var);
    match parents.len() {
        0 => {
            for &s in col_v {
                counts[s as usize] += 1;
            }
        }
        1 => {
            let col_p = data.column(parents[0]);
            for r in 0..data.n_rows() {
                counts[col_p[r] as usize * card + col_v[r] as usize] += 1;
            }
        }
        _ => {
            let cols: Vec<&[u8]> =
                parents.iter().map(|&p| data.column(p)).collect();
            for r in 0..data.n_rows() {
                let mut cfg = 0usize;
                for (k, col) in cols.iter().enumerate() {
                    cfg = cfg * parent_cards[k] + col[r] as usize;
                }
                counts[cfg * card + col_v[r] as usize] += 1;
            }
        }
    }
    FamilyCounts { var, counts, card }
}

/// Turn family counts into a CPT row-by-row with smoothing.
pub fn counts_to_cpt(
    counts: &FamilyCounts,
    var: VarId,
    parents: Vec<VarId>,
    parent_cards: Vec<usize>,
    pseudo: f64,
) -> Cpt {
    let card = counts.card;
    let n_cfg: usize = parent_cards.iter().product();
    let mut table = vec![0.0f64; n_cfg * card];
    for cfg in 0..n_cfg {
        let row = &counts.counts[cfg * card..(cfg + 1) * card];
        let total: f64 = row.iter().map(|&c| c as f64).sum::<f64>() + pseudo * card as f64;
        if total > 0.0 {
            for s in 0..card {
                table[cfg * card + s] = (row[s] as f64 + pseudo) / total;
            }
        } else {
            // No data and no smoothing: uniform fallback.
            for s in 0..card {
                table[cfg * card + s] = 1.0 / card as f64;
            }
        }
    }
    Cpt::new(var, parents, parent_cards, card, table)
}

/// One family's sufficient statistics through the counting substrate —
/// cache hit, exact superset projection, or one streaming pass; the
/// scattered counts are bit-identical to [`count_family`].
pub fn family_counts_cached(
    data: &Dataset,
    cache: &CountCache,
    var: VarId,
    parents: &[VarId],
) -> FamilyCounts {
    let mut key: Vec<VarId> = parents.to_vec();
    key.push(var);
    key.sort_unstable();
    let table = cache.table(data, &key);
    let mut order: Vec<VarId> = parents.to_vec();
    order.push(var);
    FamilyCounts {
        var,
        counts: table.permuted_counts(&order),
        card: data.cardinality(var),
    }
}

/// Learn all CPTs for a given structure by MLE (families counted through
/// a fresh count cache; see [`mle_with_cache`] to share one across
/// learning phases).
pub fn mle(data: &Dataset, dag: &Dag, opts: &MleOptions) -> BayesianNetwork {
    mle_with_cache(data, dag, opts, &CountCache::new())
}

/// MLE over a shared [`CountCache`]: a cache populated by a preceding
/// structure-learning run over the same dataset lets family tables hit
/// or project instead of rescanning rows.
pub fn mle_with_cache(
    data: &Dataset,
    dag: &Dag,
    opts: &MleOptions,
    cache: &CountCache,
) -> BayesianNetwork {
    assert_eq!(dag.n_nodes(), data.n_vars());
    let n = data.n_vars();
    let cpts: Vec<Cpt> = parallel_map(n, opts.threads, 1, |v| {
        let parents = dag.parents(v).to_vec();
        let parent_cards: Vec<usize> =
            parents.iter().map(|&p| data.cardinality(p)).collect();
        let counts = family_counts_cached(data, cache, v, &parents);
        counts_to_cpt(&counts, v, parents, parent_cards, opts.pseudo_count)
    });
    BayesianNetwork::new(
        "learned",
        data.variables().to_vec(),
        dag.clone(),
        cpts,
    )
}

/// Log-likelihood of a dataset under a network (model-selection metric and
/// regression guard for the learners).
pub fn log_likelihood(net: &BayesianNetwork, data: &Dataset) -> f64 {
    let mut ll = 0.0;
    let n = data.n_rows();
    for v in 0..net.n_vars() {
        let cpt = net.cpt(v);
        let col_v = data.column(v);
        let parents = cpt.parents.clone();
        let cols: Vec<&[u8]> = parents.iter().map(|&p| data.column(p)).collect();
        for r in 0..n {
            let mut cfg = 0usize;
            for (k, col) in cols.iter().enumerate() {
                cfg = cfg * cpt.parent_cards[k] + col[r] as usize;
            }
            ll += cpt.prob(cfg, col_v[r] as usize).max(f64::MIN_POSITIVE).ln();
        }
    }
    ll
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    #[test]
    fn counts_match_manual() {
        let net = repository::sprinkler();
        let mut rng = Pcg::seed_from(2);
        let data = forward_sample_dataset(&net, 1000, &mut rng);
        let counts = count_family(&data, 3, &[1, 2]); // wet | sprinkler, rain
        let total: u64 = counts.counts.iter().sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn mle_recovers_cpts() {
        let net = repository::asia();
        let mut rng = Pcg::seed_from(3);
        let data = forward_sample_dataset(&net, 100_000, &mut rng);
        let learned = mle(&data, net.dag(), &MleOptions { pseudo_count: 0.0, threads: 1 });
        // Compare the smoke prior and the bronc|smoke CPT.
        let smoke = net.var_index("smoke").unwrap();
        let bronc = net.var_index("bronc").unwrap();
        assert!((learned.cpt(smoke).prob(0, 1) - 0.5).abs() < 0.01);
        assert!((learned.cpt(bronc).prob(1, 1) - 0.6).abs() < 0.02);
    }

    #[test]
    fn smoothing_avoids_zeros() {
        let net = repository::earthquake();
        let mut rng = Pcg::seed_from(4);
        // Tiny sample: rare configs (alarm given burglary+earthquake) unseen.
        let data = forward_sample_dataset(&net, 50, &mut rng);
        let learned = mle(&data, net.dag(), &MleOptions::default());
        for v in 0..learned.n_vars() {
            assert!(learned.cpt(v).table.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn cached_family_counts_bit_identical() {
        let net = repository::asia();
        let mut rng = Pcg::seed_from(8);
        let data = forward_sample_dataset(&net, 3_000, &mut rng);
        let cache = CountCache::new();
        for v in 0..net.n_vars() {
            let parents = net.dag().parents(v).to_vec();
            let direct = count_family(&data, v, &parents);
            let cached = family_counts_cached(&data, &cache, v, &parents);
            assert_eq!(direct.counts, cached.counts, "family of {v}");
            assert_eq!(direct.card, cached.card);
        }
        // And through a *projection*: warm a superset table, then derive
        // a smaller family from it instead of rescanning.
        let warm = CountCache::new();
        warm.table(&data, &[0, 1, 2]);
        let sub = family_counts_cached(&data, &warm, 1, &[0]);
        assert_eq!(sub.counts, count_family(&data, 1, &[0]).counts);
        let stats = warm.stats();
        assert_eq!(stats.projections, 1, "{stats:?}");
        assert_eq!(stats.scans, 1, "{stats:?}");
    }

    #[test]
    fn mle_with_shared_cache_identical() {
        let net = repository::survey();
        let mut rng = Pcg::seed_from(9);
        let data = forward_sample_dataset(&net, 4_000, &mut rng);
        let plain = mle(&data, net.dag(), &MleOptions::default());
        let cache = CountCache::new();
        let shared = mle_with_cache(&data, net.dag(), &MleOptions::default(), &cache);
        for v in 0..net.n_vars() {
            assert_eq!(plain.cpt(v).table, shared.cpt(v).table, "cpt of {v}");
        }
        assert!(cache.stats().lookups() >= net.n_vars() as u64);
    }

    #[test]
    fn parallel_mle_matches_sequential() {
        let net = repository::survey();
        let mut rng = Pcg::seed_from(5);
        let data = forward_sample_dataset(&net, 5_000, &mut rng);
        let a = mle(&data, net.dag(), &MleOptions { threads: 1, ..Default::default() });
        let b = mle(&data, net.dag(), &MleOptions { threads: 4, ..Default::default() });
        for v in 0..a.n_vars() {
            assert_eq!(a.cpt(v).table, b.cpt(v).table);
        }
    }

    #[test]
    fn more_data_higher_likelihood_of_truth() {
        let net = repository::cancer();
        let mut rng = Pcg::seed_from(6);
        let data = forward_sample_dataset(&net, 20_000, &mut rng);
        let learned = mle(&data, net.dag(), &MleOptions::default());
        let ll_true = log_likelihood(&net, &data);
        let ll_learned = log_likelihood(&learned, &data);
        // MLE fits the sample at least as well as the generator (up to
        // smoothing slack).
        assert!(ll_learned >= ll_true - data.n_rows() as f64 * 0.01);
    }
}
