//! Parameter learning: maximum-likelihood estimation of CPTs given a
//! structure, with Laplace smoothing and cache-friendly sufficient-
//! statistics counting (paper §2 + optimization (ii)).

use crate::core::{Dataset, VarId};
use crate::graph::Dag;
use crate::network::{BayesianNetwork, Cpt};
use crate::parallel::parallel_map;

/// Options for MLE.
#[derive(Clone, Debug)]
pub struct MleOptions {
    /// Laplace/Dirichlet pseudo-count added to every cell (0 = pure MLE;
    /// rows with zero observations then fall back to uniform).
    pub pseudo_count: f64,
    /// Worker threads (families are counted independently).
    pub threads: usize,
}

impl Default for MleOptions {
    fn default() -> Self {
        MleOptions { pseudo_count: 1.0, threads: 1 }
    }
}

/// Sufficient statistics for one family: counts over
/// `(parent configuration, child state)`.
#[derive(Clone, Debug, Default)]
pub struct FamilyCounts {
    pub var: VarId,
    pub counts: Vec<u64>,
    pub card: usize,
}

/// Count one family's sufficient statistics in a single column-major pass:
/// the child and parent columns are each contiguous, so the scan touches
/// `(1 + #parents)` dense arrays sequentially (optimization ii).
pub fn count_family(data: &Dataset, var: VarId, parents: &[VarId]) -> FamilyCounts {
    let card = data.cardinality(var);
    let parent_cards: Vec<usize> =
        parents.iter().map(|&p| data.cardinality(p)).collect();
    let n_cfg: usize = parent_cards.iter().product();
    let mut counts = vec![0u64; n_cfg * card];
    let col_v = data.column(var);
    match parents.len() {
        0 => {
            for &s in col_v {
                counts[s as usize] += 1;
            }
        }
        1 => {
            let col_p = data.column(parents[0]);
            for r in 0..data.n_rows() {
                counts[col_p[r] as usize * card + col_v[r] as usize] += 1;
            }
        }
        _ => {
            let cols: Vec<&[u8]> =
                parents.iter().map(|&p| data.column(p)).collect();
            for r in 0..data.n_rows() {
                let mut cfg = 0usize;
                for (k, col) in cols.iter().enumerate() {
                    cfg = cfg * parent_cards[k] + col[r] as usize;
                }
                counts[cfg * card + col_v[r] as usize] += 1;
            }
        }
    }
    FamilyCounts { var, counts, card }
}

/// Turn family counts into a CPT row-by-row with smoothing.
pub fn counts_to_cpt(
    counts: &FamilyCounts,
    var: VarId,
    parents: Vec<VarId>,
    parent_cards: Vec<usize>,
    pseudo: f64,
) -> Cpt {
    let card = counts.card;
    let n_cfg: usize = parent_cards.iter().product();
    let mut table = vec![0.0f64; n_cfg * card];
    for cfg in 0..n_cfg {
        let row = &counts.counts[cfg * card..(cfg + 1) * card];
        let total: f64 = row.iter().map(|&c| c as f64).sum::<f64>() + pseudo * card as f64;
        if total > 0.0 {
            for s in 0..card {
                table[cfg * card + s] = (row[s] as f64 + pseudo) / total;
            }
        } else {
            // No data and no smoothing: uniform fallback.
            for s in 0..card {
                table[cfg * card + s] = 1.0 / card as f64;
            }
        }
    }
    Cpt::new(var, parents, parent_cards, card, table)
}

/// Learn all CPTs for a given structure by MLE.
pub fn mle(data: &Dataset, dag: &Dag, opts: &MleOptions) -> BayesianNetwork {
    assert_eq!(dag.n_nodes(), data.n_vars());
    let n = data.n_vars();
    let cpts: Vec<Cpt> = parallel_map(n, opts.threads, 1, |v| {
        let parents = dag.parents(v).to_vec();
        let parent_cards: Vec<usize> =
            parents.iter().map(|&p| data.cardinality(p)).collect();
        let counts = count_family(data, v, &parents);
        counts_to_cpt(&counts, v, parents, parent_cards, opts.pseudo_count)
    });
    BayesianNetwork::new(
        "learned",
        data.variables().to_vec(),
        dag.clone(),
        cpts,
    )
}

/// Log-likelihood of a dataset under a network (model-selection metric and
/// regression guard for the learners).
pub fn log_likelihood(net: &BayesianNetwork, data: &Dataset) -> f64 {
    let mut ll = 0.0;
    let n = data.n_rows();
    for v in 0..net.n_vars() {
        let cpt = net.cpt(v);
        let col_v = data.column(v);
        let parents = cpt.parents.clone();
        let cols: Vec<&[u8]> = parents.iter().map(|&p| data.column(p)).collect();
        for r in 0..n {
            let mut cfg = 0usize;
            for (k, col) in cols.iter().enumerate() {
                cfg = cfg * cpt.parent_cards[k] + col[r] as usize;
            }
            ll += cpt.prob(cfg, col_v[r] as usize).max(f64::MIN_POSITIVE).ln();
        }
    }
    ll
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::repository;
    use crate::rng::Pcg;
    use crate::sampling::forward_sample_dataset;

    #[test]
    fn counts_match_manual() {
        let net = repository::sprinkler();
        let mut rng = Pcg::seed_from(2);
        let data = forward_sample_dataset(&net, 1000, &mut rng);
        let counts = count_family(&data, 3, &[1, 2]); // wet | sprinkler, rain
        let total: u64 = counts.counts.iter().sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn mle_recovers_cpts() {
        let net = repository::asia();
        let mut rng = Pcg::seed_from(3);
        let data = forward_sample_dataset(&net, 100_000, &mut rng);
        let learned = mle(&data, net.dag(), &MleOptions { pseudo_count: 0.0, threads: 1 });
        // Compare the smoke prior and the bronc|smoke CPT.
        let smoke = net.var_index("smoke").unwrap();
        let bronc = net.var_index("bronc").unwrap();
        assert!((learned.cpt(smoke).prob(0, 1) - 0.5).abs() < 0.01);
        assert!((learned.cpt(bronc).prob(1, 1) - 0.6).abs() < 0.02);
    }

    #[test]
    fn smoothing_avoids_zeros() {
        let net = repository::earthquake();
        let mut rng = Pcg::seed_from(4);
        // Tiny sample: rare configs (alarm given burglary+earthquake) unseen.
        let data = forward_sample_dataset(&net, 50, &mut rng);
        let learned = mle(&data, net.dag(), &MleOptions::default());
        for v in 0..learned.n_vars() {
            assert!(learned.cpt(v).table.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn parallel_mle_matches_sequential() {
        let net = repository::survey();
        let mut rng = Pcg::seed_from(5);
        let data = forward_sample_dataset(&net, 5_000, &mut rng);
        let a = mle(&data, net.dag(), &MleOptions { threads: 1, ..Default::default() });
        let b = mle(&data, net.dag(), &MleOptions { threads: 4, ..Default::default() });
        for v in 0..a.n_vars() {
            assert_eq!(a.cpt(v).table, b.cpt(v).table);
        }
    }

    #[test]
    fn more_data_higher_likelihood_of_truth() {
        let net = repository::cancer();
        let mut rng = Pcg::seed_from(6);
        let data = forward_sample_dataset(&net, 20_000, &mut rng);
        let learned = mle(&data, net.dag(), &MleOptions::default());
        let ll_true = log_likelihood(&net, &data);
        let ll_learned = log_likelihood(&learned, &data);
        // MLE fits the sample at least as well as the generator (up to
        // smoothing slack).
        assert!(ll_learned >= ll_true - data.n_rows() as f64 * 0.01);
    }
}
