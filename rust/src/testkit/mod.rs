//! In-repo property-based testing (the offline image has no proptest).
//!
//! [`property`] runs a closure over many deterministically generated cases
//! from a seeded [`Pcg`]; on failure it reports the case index and seed so
//! the exact case replays. Generators for the library's domain types live
//! here too (random canonical potentials, random DAGs, random evidence),
//! shared by unit tests, integration tests and the fuzz-ish invariant
//! suites in `rust/tests/`.

use crate::core::{Evidence, VarId};
use crate::graph::Dag;
use crate::network::{BayesianNetwork, synthetic::SyntheticSpec};
use crate::potential::PotentialTable;
use crate::rng::Pcg;

/// Run `cases` generated test cases. The closure receives a per-case RNG
/// (derived from `seed` + case index, so failures replay independently of
/// how many draws earlier cases made) and should panic on violation.
pub fn property(name: &str, seed: u64, cases: usize, mut body: impl FnMut(&mut Pcg)) {
    for i in 0..cases {
        let mut rng = Pcg::seed_from(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {i} (seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random sorted scope of `k` variables drawn from `0..universe`, with
/// cardinalities in `2..=max_card`.
pub fn gen_scope(
    rng: &mut Pcg,
    universe: usize,
    k: usize,
    max_card: usize,
) -> (Vec<VarId>, Vec<usize>) {
    let mut vars = rng.choose_k(universe, k);
    vars.sort_unstable();
    let cards = vars.iter().map(|_| rng.range(2, max_card + 1)).collect();
    (vars, cards)
}

/// Random potential table with entries in `(0, 10)`.
pub fn gen_potential(
    rng: &mut Pcg,
    universe: usize,
    max_vars: usize,
    max_card: usize,
) -> PotentialTable {
    let k = rng.range(0, max_vars + 1);
    let (vars, cards) = gen_scope(rng, universe, k, max_card);
    let mut t = PotentialTable::zeros(vars, cards);
    for x in t.data_mut() {
        *x = rng.next_f64() * 10.0 + 1e-3;
    }
    t
}

/// Pair of random potentials guaranteed to agree on shared cardinalities
/// (drawn over a common universe with shared cardinality table).
pub fn gen_potential_pair(
    rng: &mut Pcg,
    universe: usize,
    max_vars: usize,
    max_card: usize,
) -> (PotentialTable, PotentialTable) {
    let cards_of: Vec<usize> =
        (0..universe).map(|_| rng.range(2, max_card + 1)).collect();
    let draw = |rng: &mut Pcg| {
        let k = rng.range(1, max_vars + 1);
        let mut vars = rng.choose_k(universe, k);
        vars.sort_unstable();
        let cards: Vec<usize> = vars.iter().map(|&v| cards_of[v]).collect();
        let mut t = PotentialTable::zeros(vars, cards);
        for x in t.data_mut() {
            *x = rng.next_f64() * 10.0 + 1e-3;
        }
        t
    };
    let a = draw(rng);
    let b = draw(rng);
    (a, b)
}

/// Random DAG over `n` nodes with max in-degree `max_parents`.
pub fn gen_dag(rng: &mut Pcg, n: usize, max_parents: usize) -> Dag {
    let mut d = Dag::new(n);
    for v in 1..n {
        let k = rng.range(0, max_parents.min(v) + 1);
        for p in rng.choose_k(v, k) {
            d.add_edge_unchecked(p, v);
        }
    }
    d
}

/// Random small Bayesian network (for engine cross-checks).
pub fn gen_network(rng: &mut Pcg, n: usize) -> BayesianNetwork {
    let mut spec = SyntheticSpec::new("prop", n);
    spec.card_range = (2, 3);
    spec.max_in_degree = 3;
    spec.generate(rng.next_u64())
}

/// Random evidence over `k` distinct variables of a network.
pub fn gen_evidence(rng: &mut Pcg, net: &BayesianNetwork, k: usize) -> Evidence {
    let vars = rng.choose_k(net.n_vars(), k);
    vars.into_iter()
        .map(|v| (v, rng.below(net.cardinality(v))))
        .collect()
}

/// Bounded pool of random evidence sets over `k` variables each — the
/// shared serving-traffic model (serving traffic repeats itself, which is
/// what the calibration cache exploits). Used by the `serve-query` CLI,
/// the e2e serving example and the serving bench so the three drivers
/// stay in sync.
pub fn gen_evidence_pool(
    rng: &mut Pcg,
    net: &BayesianNetwork,
    size: usize,
    k: usize,
) -> Vec<Evidence> {
    (0..size)
        .map(|_| gen_evidence(rng, net, k.min(net.n_vars())))
        .collect()
}

/// Pool of evidence sets arranged as nested chains `E1 ⊂ E2 ⊂ … ⊂ Ek`
/// (`chains` chains of `depth` sets each) — the *prefix-heavy* traffic
/// shape (dashboard panels and diagnostic presets differing by one or two
/// observations) that the warm-start calibration cache exploits: a miss on
/// `E_{i+1}` finds `E_i` cached and recalibrates incrementally. Shared by
/// the `serve-query --prefix-pool` demo and the warm-start bench.
pub fn gen_evidence_chain_pool(
    rng: &mut Pcg,
    net: &BayesianNetwork,
    chains: usize,
    depth: usize,
) -> Vec<Evidence> {
    let mut out = Vec::with_capacity(chains * depth);
    for _ in 0..chains {
        let mut ev = Evidence::new();
        for v in rng.choose_k(net.n_vars(), depth.min(net.n_vars())) {
            ev.set(v, rng.below(net.cardinality(v)));
            out.push(ev.clone());
        }
    }
    out
}

/// A query target outside the evidence, when one can be found in a few
/// draws (falls back to variable 0 — serving layers answer evidence
/// variables with a point mass, so the fallback stays well-defined).
pub fn gen_query_var(rng: &mut Pcg, net: &BayesianNetwork, ev: &Evidence) -> VarId {
    (0..16)
        .map(|_| rng.below(net.n_vars()))
        .find(|&v| ev.get(v).is_none())
        .unwrap_or(0)
}

/// Assert two distributions are close in total variation.
pub fn assert_close_dist(p: &[f64], q: &[f64], tol: f64, context: &str) {
    let tv = crate::metrics::total_variation(p, q);
    assert!(
        tv <= tol,
        "{context}: distributions differ (TV {tv:.5} > {tol}): {p:?} vs {q:?}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property("counting", 1, 25, |_| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn property_reports_failure() {
        property("fails", 2, 10, |rng| {
            assert!(rng.next_f64() < 0.5, "half the cases fail");
        });
    }

    #[test]
    fn generated_potentials_valid() {
        let mut rng = Pcg::seed_from(3);
        for _ in 0..50 {
            let t = gen_potential(&mut rng, 8, 4, 4);
            assert_eq!(t.len(), t.cards().iter().product::<usize>().max(1));
            assert!(t.data().iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn generated_pairs_share_cards() {
        let mut rng = Pcg::seed_from(4);
        for _ in 0..50 {
            let (a, b) = gen_potential_pair(&mut rng, 6, 3, 4);
            for &v in a.vars() {
                if let (Some(ca), Some(cb)) = (a.card_of(v), b.card_of(v)) {
                    assert_eq!(ca, cb);
                }
            }
        }
    }

    #[test]
    fn generated_dags_acyclic() {
        let mut rng = Pcg::seed_from(5);
        for _ in 0..20 {
            let d = gen_dag(&mut rng, 12, 3);
            assert!(d.topological_order().is_some());
        }
    }

    #[test]
    fn chain_pool_is_nested() {
        let mut rng = Pcg::seed_from(7);
        let net = gen_network(&mut rng, 10);
        let pool = gen_evidence_chain_pool(&mut rng, &net, 3, 4);
        assert_eq!(pool.len(), 12);
        for chain in pool.chunks(4) {
            for w in chain.windows(2) {
                assert!(w[0].is_subset_of(&w[1]), "chain must be nested");
                assert_eq!(w[0].len() + 1, w[1].len());
            }
        }
    }

    #[test]
    fn generated_evidence_in_range() {
        let mut rng = Pcg::seed_from(6);
        let net = gen_network(&mut rng, 10);
        let ev = gen_evidence(&mut rng, &net, 3);
        assert_eq!(ev.len(), 3);
        for (v, s) in ev.iter() {
            assert!(s < net.cardinality(v));
        }
    }
}
