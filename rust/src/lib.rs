//! # Fast-PGM — fast probabilistic graphical model learning and inference
//!
//! A Rust reproduction of *Fast-PGM: Fast Probabilistic Graphical Model
//! Learning and Inference* (Jiang et al., 2024), built as the L3 layer of a
//! three-layer Rust + JAX + Pallas stack (see `DESIGN.md`).
//!
//! The library covers every task the paper claims:
//!
//! * **Structure learning** — the PC-stable algorithm with conditional-
//!   independence-level parallelism driven by a dynamic work pool, plus
//!   score-based greedy hill climbing with a parallel candidate scan
//!   ([`structure`]).
//! * **Parameter learning** — maximum-likelihood estimation with Laplace
//!   smoothing and cache-friendly sufficient-statistics counting
//!   ([`parameter`]).
//! * **Shared counting substrate** — every learning-side consumer (CI
//!   tests, structure scores, MLE, the classifier) draws its integer
//!   count tables from one grouped-counting engine with a sharded,
//!   subset-projecting cache ([`counts`]); the end-to-end
//!   data → structure → parameters → compiled-serving flow is packaged
//!   as [`learn::Pipeline`].
//! * **Exact inference** — junction tree (Lauritzen–Spiegelhalter) with
//!   hybrid inter-/intra-clique parallelism and variable elimination
//!   ([`inference::exact`]).
//! * **Approximate inference** — loopy belief propagation, probabilistic
//!   logic sampling, likelihood weighting, self-importance sampling, AIS-BN
//!   and EPIS-BN, all with sample-level parallelism
//!   ([`inference::approx`]).
//! * **Auxiliary tooling** — sample-set generation ([`sampling`]), format
//!   transformation (BIF ⇄ native `.fpgm`, [`io`]), structural Hamming
//!   distance and Hellinger distance metrics ([`metrics`]), and a complete
//!   classification pipeline ([`classify`]).
//!
//! On top of the library sits a serving-style coordinator ([`coordinator`])
//! with two request paths:
//!
//! * **Classify** — batches classification requests onto an AOT-compiled
//!   XLA artifact (authored in JAX + Pallas at build time, executed
//!   through PJRT by [`runtime`]; `xla-runtime` feature) — Python is never
//!   on the request path.
//! * **Query** — serves arbitrary posterior/MAP queries through the
//!   compile-vs-query split ([`inference::exact::CompiledTree`] built once
//!   per network, [`inference::exact::CalibratedTree`] snapshots per
//!   evidence set, LRU-cached by [`inference::exact::QueryEngine`]), with
//!   evidence-grouped dynamic batching over the shared work pool
//!   ([`coordinator::QueryRouter`]). Under load, batch-priority queries
//!   shed to an approximate tier: the samplers wrapped behind the serving
//!   [`inference::engine::InferenceEngine`] trait, fanning chunked sample
//!   budgets over the same pool with per-chunk RNG streams and adaptive
//!   stopping ([`inference::engine::ApproxEngine`]).
//!
//! The serving surface scales horizontally through the sharded fabric
//! ([`coordinator::fabric`]): a frontend routes queries to shard processes
//! by consistent hashing on the evidence signature (keeping each shard's
//! warm-start caches hot) over a versioned binary wire protocol, with
//! supervised respawn and in-process fallback. The stable public facade
//! for all of it is [`serving`].
//!
//! Everything measurable publishes through the observability substrate
//! ([`obs`]): per-query stage spans accumulated into mergeable
//! log-bucket histograms, one process-global metrics registry, and a
//! zero-dependency Prometheus/JSON exporter (`serve-query
//! --stats-addr`) — see `docs/OBSERVABILITY.md`.

pub mod benchkit;
pub mod classify;
pub mod cli;
pub mod coordinator;
pub mod core;
pub mod counts;
pub mod faults;
pub mod graph;
pub mod inference;
pub mod io;
pub mod learn;
pub mod metrics;
pub mod mrf;
pub mod network;
pub mod obs;
pub mod parallel;
pub mod parameter;
pub mod potential;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod serving;
pub mod structure;
pub mod testkit;

/// Commonly used types, re-exported for convenience.
pub mod prelude {
    pub use crate::core::{Assignment, Dataset, Evidence, VarId, Variable};
    pub use crate::graph::{Dag, Pdag, UGraph};
    pub use crate::inference::{InferenceEngine, Posterior};
    pub use crate::network::BayesianNetwork;
    pub use crate::potential::PotentialTable;
    pub use crate::rng::Pcg;
}
