//! The stable public serving facade.
//!
//! Everything an embedding application needs to serve posterior queries —
//! in-process or across the sharded fabric — re-exported under one path,
//! so internal module moves never break downstream code:
//!
//! ```no_run
//! use fastpgm::network::repository;
//! use fastpgm::serving::{
//!     BatcherConfig, QueryEngineConfig, QueryRequest, QueryRouter,
//! };
//! use fastpgm::prelude::Evidence;
//!
//! let mut router = QueryRouter::new(2);
//! router.register(
//!     "asia",
//!     &repository::asia(),
//!     QueryEngineConfig::new().with_cache_capacity(128),
//!     BatcherConfig::new(),
//! );
//! let reply = router
//!     .query_routed("asia", QueryRequest::marginal(5, Evidence::new().with(0, 1)))
//!     .unwrap();
//! assert_eq!(reply.engine, "exact");
//! ```
//!
//! The four config types (`QueryEngineConfig`, `ApproxConfig`,
//! `BatcherConfig`, `ChunkedConfig`) are `#[non_exhaustive]` with
//! builder-style `with_*` constructors, and every failure on this surface
//! is a typed [`ServingError`] — the same contract, with the same error
//! codes, that the fabric wire protocol (`docs/WIRE_PROTOCOL.md`) encodes.

// Request/reply vocabulary.
pub use crate::coordinator::{
    AnswerTier, QueryPriority, QueryQos, QueryReply, QueryRequest, QueryTarget,
    RoutedReply,
};

// Engines, routers, and their configuration.
pub use crate::coordinator::{
    ApproxConfig, BatcherConfig, DynamicBatcher, QueryModelStats, QueryRouter,
    QueryService, Router, RouterStats, ServingMetrics,
};
pub use crate::inference::approx::ApproxOptions;
pub use crate::inference::engine::{
    ApproxEngine, ChunkedConfig, EngineChoice, InferenceEngine, SamplerKind,
};
pub use crate::inference::exact::{
    CalibrationMode, EliminationOrderHeuristic, KernelMode, QueryEngine,
    QueryEngineConfig, QueryEngineStats,
};

// Typed serving errors (shared by the in-process path and the wire).
pub use crate::coordinator::ServingError;

// Observability: the cost knob, the stage model, the registry, and the
// stats endpoint (`docs/OBSERVABILITY.md`).
pub use crate::obs::{
    Collector, LatencyHistogram, ObsConfig, ObsLevel, Registry, Sample, SpanRecord,
    Stage, StageSet, StatsServer, TraceLog, Value,
};

// The distributed fabric.
pub use crate::coordinator::fabric::wire;
pub use crate::coordinator::{
    FabricConfig, FabricMetrics, Frontend, ModelSpec, ProcessLauncher, RoutingPolicy,
    ShardConfig, ShardHandle, ShardLauncher, ShardWorker, ThreadLauncher,
    SHARD_READY_PREFIX,
};

// Resilience: breakers, backoff, retry budgets (`docs/ROBUSTNESS.md`).
pub use crate::coordinator::{
    Admit, Backoff, BreakerConfig, BreakerState, CircuitBreaker, RetryBudget,
    ShardedRetryBudget,
};

// Deterministic fault injection for chaos tests and `--fault-plan`.
pub use crate::faults::{
    schedule_digest, FaultAction, FaultEvent, FaultHook, FaultKind, FaultPlan,
    FaultRule, FaultSite, Faults,
};

// Crash-safe model lifecycle: checksummed snapshots, total (panic-free)
// decoders, validation gates, quarantining ingestion, and gated rollout
// (`docs/ROBUSTNESS.md`, "Model lifecycle").
pub use crate::coordinator::{
    register_gated, shadow_compare, GateReport, ShadowReport, DEFAULT_SPOT_CHECKS,
};
pub use crate::io::csv::{IngestOptions, IngestReport};
pub use crate::io::fpgm::SnapshotInfo;
pub use crate::io::model::{
    validate_network, validate_raw, ModelError, RawNet, ValidationReport,
};
