//! Deterministic fault injection for the serving fabric.
//!
//! Chaos testing is only useful if a failure found once can be found
//! again: every decision this module makes is a pure function of a
//! **seed**, so a fault schedule replays exactly from
//! `serve-query --fault-plan "seed=42,…"` or from a [`FaultPlan`] in a
//! test. The design mirrors [`crate::obs::ObsConfig`]'s
//! zero-cost-when-off pattern: call sites hold an
//! `Option<Arc<Faults>>` ([`FaultHook`]) and a disabled hook costs one
//! branch on the hot path — no clock reads, no hashing, no locks.
//!
//! ## Sites
//!
//! Faults are injected at named points in the fabric I/O paths
//! ([`FaultSite`]):
//!
//! | site             | where                                               |
//! |------------------|-----------------------------------------------------|
//! | `connect`        | frontend dials a shard (refuse)                     |
//! | `frontend_send`  | frontend writes a request frame                     |
//! | `frontend_recv`  | frontend reads a reply frame                        |
//! | `shard_recv`     | shard has read a request frame                      |
//! | `serve`          | shard is about to answer a query (slowdown/stall)   |
//! | `shard_send`     | shard writes a reply frame                          |
//! | `corrupt_row`    | CSV ingestion is about to parse a data row          |
//! | `truncate_model` | a `.fpgm` snapshot is about to hit the disk         |
//! | `slow_counts`    | the learner is about to sweep the dataset counts    |
//! | `learn_kill`     | the learner crosses a pipeline phase boundary       |
//!
//! The last four extend chaos coverage past the wire into the model/data
//! plane (`--learn-from`): corrupted ingestion rows, torn or bit-flipped
//! snapshot writes, slow counting passes, and a learner dying mid-run.
//!
//! ## Determinism model
//!
//! Each site keeps a sequence counter; the decision for the *k*-th
//! evaluation at a site is `mix(seed, site, rule, k)` compared against
//! the rule's probability — independent of wall clock, thread timing,
//! or what other sites did. A single-threaded client therefore replays
//! an identical fault sequence from the same seed; concurrent clients
//! see the same per-site decision *stream* with interleaving decided by
//! arrival order. [`schedule_digest`] folds the first decisions of
//! every site into one hash that depends only on `(seed, rules)` —
//! `serve-query` prints it so CI can assert two runs of the same plan
//! agree.
//!
//! Frame corruption ([`Faults::corrupt_frame`]) deliberately flips a
//! bit only inside the 4-byte wire magic: every such flip is a prompt,
//! unambiguous decode error at the peer, so live chaos runs stay
//! error-shaped — never a silent wrong answer (payload bit), never a
//! read blocked on a mangled length until the I/O timeout. Arbitrary
//! single-byte corruption of every frame region is covered by the wire
//! property tests instead (pure decode, no I/O).

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A named injection point in the fabric I/O paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Frontend dials a shard.
    Connect,
    /// Frontend writes a request frame.
    FrontendSend,
    /// Frontend reads a reply frame.
    FrontendRecv,
    /// Shard has read a request frame.
    ShardRecv,
    /// Shard is about to serve a query.
    Serve,
    /// Shard writes a reply frame.
    ShardSend,
    /// CSV ingestion is about to parse a data row (corrupt it first).
    CorruptRow,
    /// A `.fpgm` snapshot is about to be written (tear or flip it).
    TruncateModel,
    /// The learner is about to sweep dataset counts (slow it down).
    SlowCounts,
    /// The learner crosses a phase boundary (kill it mid-learn).
    LearnKill,
}

impl FaultSite {
    pub const ALL: [FaultSite; 10] = [
        FaultSite::Connect,
        FaultSite::FrontendSend,
        FaultSite::FrontendRecv,
        FaultSite::ShardRecv,
        FaultSite::Serve,
        FaultSite::ShardSend,
        FaultSite::CorruptRow,
        FaultSite::TruncateModel,
        FaultSite::SlowCounts,
        FaultSite::LearnKill,
    ];

    /// Stable lowercase label (spec syntax, event log, metric label).
    pub fn label(self) -> &'static str {
        match self {
            FaultSite::Connect => "connect",
            FaultSite::FrontendSend => "frontend_send",
            FaultSite::FrontendRecv => "frontend_recv",
            FaultSite::ShardRecv => "shard_recv",
            FaultSite::Serve => "serve",
            FaultSite::ShardSend => "shard_send",
            FaultSite::CorruptRow => "corrupt_row",
            FaultSite::TruncateModel => "truncate_model",
            FaultSite::SlowCounts => "slow_counts",
            FaultSite::LearnKill => "learn_kill",
        }
    }

    pub fn parse(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|site| site.label() == s)
    }

    fn index(self) -> usize {
        self as usize
    }

    /// Per-site hash salt so two sites never share a decision stream.
    fn salt(self) -> u64 {
        (self as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }
}

/// What kind of fault a rule injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Lose the frame (the peer's read times out).
    Drop,
    /// Sleep before proceeding (slow shard / slow network).
    Delay,
    /// Flip one deterministic bit in the encoded frame.
    Corrupt,
    /// Refuse the connection attempt.
    Refuse,
    /// Kill the connection abruptly (mid-reply when at `shard_send`).
    Kill,
    /// Long sleep — a stalled-but-alive shard.
    Stall,
}

impl FaultKind {
    pub const ALL: [FaultKind; 6] = [
        FaultKind::Drop,
        FaultKind::Delay,
        FaultKind::Corrupt,
        FaultKind::Refuse,
        FaultKind::Kill,
        FaultKind::Stall,
    ];

    pub fn label(self) -> &'static str {
        match self {
            FaultKind::Drop => "drop",
            FaultKind::Delay => "delay",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Refuse => "refuse",
            FaultKind::Kill => "kill",
            FaultKind::Stall => "stall",
        }
    }

    pub fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.iter().copied().find(|k| k.label() == s)
    }

    /// Where this kind lands when the spec names no site.
    fn default_site(self) -> FaultSite {
        match self {
            FaultKind::Drop => FaultSite::ShardSend,
            FaultKind::Delay => FaultSite::Serve,
            FaultKind::Corrupt => FaultSite::ShardSend,
            FaultKind::Refuse => FaultSite::Connect,
            FaultKind::Kill => FaultSite::ShardSend,
            FaultKind::Stall => FaultSite::Serve,
        }
    }

    /// Default duration for the kinds that sleep.
    fn default_millis(self) -> u64 {
        match self {
            FaultKind::Delay => 5,
            FaultKind::Stall => 250,
            _ => 0,
        }
    }

    fn has_duration(self) -> bool {
        matches!(self, FaultKind::Delay | FaultKind::Stall)
    }
}

/// One injection rule: at `site` (optionally scoped to one shard),
/// inject `kind` with probability `prob` per decision.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRule {
    pub kind: FaultKind,
    /// Per-decision probability in `[0, 1]`.
    pub prob: f64,
    pub site: FaultSite,
    /// `None` = any shard.
    pub shard: Option<u32>,
    /// Sleep length for `Delay`/`Stall` (ignored otherwise).
    pub millis: u64,
}

impl fmt::Display for FaultRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}={}", self.kind.label(), self.prob)?;
        if self.kind.has_duration() {
            write!(f, "x{}ms", self.millis)?;
        }
        write!(f, "@{}", self.site.label())?;
        if let Some(s) = self.shard {
            write!(f, "/shard{s}")?;
        }
        Ok(())
    }
}

/// A seedable, replayable fault schedule: a seed plus an ordered rule
/// list (first matching rule wins at each decision).
///
/// Spec syntax (`--fault-plan`):
///
/// ```text
/// seed=42,delay=0.2x5ms@serve/shard0,corrupt=0.05@shard_send,kill=0.02
/// ```
///
/// Each item is `seed=N` or `kind=prob[xMILLISms][@site][/shardN]` with
/// kinds `drop|delay|corrupt|refuse|kill|stall` and sites
/// `connect|frontend_send|frontend_recv|shard_recv|serve|shard_send|`
/// `corrupt_row|truncate_model|slow_counts|learn_kill`.
/// A rule with no `@site` lands at its kind's natural site (e.g.
/// `refuse` → `connect`, `delay` → `serve`); the learning-path sites are
/// only reached when named explicitly (`corrupt=0.2@corrupt_row`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// An empty plan with a seed — add rules via [`FaultPlan::with_rule`].
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: Vec::new() }
    }

    pub fn with_rule(mut self, rule: FaultRule) -> FaultPlan {
        self.rules.push(rule);
        self
    }

    /// Convenience builder: `kind` with `prob` at `site`.
    pub fn with(mut self, kind: FaultKind, prob: f64, site: FaultSite) -> FaultPlan {
        self.rules.push(FaultRule {
            kind,
            prob,
            site,
            shard: None,
            millis: kind.default_millis(),
        });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Parse the `--fault-plan` spec syntax. Errors name the offending
    /// item so a typo in a chaos run fails fast instead of silently
    /// injecting nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for raw in spec.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            let (key, value) = item
                .split_once('=')
                .ok_or_else(|| format!("fault-plan item {item:?}: expected key=value"))?;
            if key == "seed" {
                plan.seed = value
                    .parse()
                    .map_err(|e| format!("fault-plan seed {value:?}: {e}"))?;
                continue;
            }
            let kind = FaultKind::parse(key)
                .ok_or_else(|| format!("fault-plan item {item:?}: unknown kind {key:?}"))?;
            let mut rest = value;
            let mut shard = None;
            if let Some(i) = rest.find("/shard") {
                let id = &rest[i + "/shard".len()..];
                shard = Some(
                    id.parse()
                        .map_err(|e| format!("fault-plan item {item:?}: shard {id:?}: {e}"))?,
                );
                rest = &rest[..i];
            }
            let mut site = None;
            if let Some(i) = rest.find('@') {
                let name = &rest[i + 1..];
                site = Some(FaultSite::parse(name).ok_or_else(|| {
                    format!("fault-plan item {item:?}: unknown site {name:?}")
                })?);
                rest = &rest[..i];
            }
            let mut millis = kind.default_millis();
            if let Some(i) = rest.find('x') {
                let dur = &rest[i + 1..];
                let dur = dur.strip_suffix("ms").ok_or_else(|| {
                    format!("fault-plan item {item:?}: duration {dur:?} must end in ms")
                })?;
                millis = dur
                    .parse()
                    .map_err(|e| format!("fault-plan item {item:?}: duration: {e}"))?;
                rest = &rest[..i];
            }
            let prob: f64 = rest
                .parse()
                .map_err(|e| format!("fault-plan item {item:?}: probability: {e}"))?;
            if !(0.0..=1.0).contains(&prob) {
                return Err(format!(
                    "fault-plan item {item:?}: probability {prob} outside [0, 1]"
                ));
            }
            plan.rules.push(FaultRule {
                kind,
                prob,
                site: site.unwrap_or_else(|| kind.default_site()),
                shard,
                millis,
            });
        }
        Ok(plan)
    }

    /// Arm the plan into a live [`Faults`] instance. `scope` bakes in a
    /// shard id for processes that *are* one shard (shard workers pass
    /// their own id; the frontend passes `None` and scopes per call).
    pub fn arm(&self, scope: Option<u32>) -> Arc<Faults> {
        Arc::new(Faults {
            plan: self.clone(),
            scope,
            enabled: AtomicBool::new(true),
            counters: Default::default(),
            corrupt_seq: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            events: Mutex::new(VecDeque::new()),
        })
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for rule in &self.rules {
            write!(f, ",{rule}")?;
        }
        Ok(())
    }
}

/// The action a call site must take after consulting [`Faults::decide`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// No fault — proceed normally.
    None,
    /// Lose the frame: skip the write/processing step.
    Drop,
    /// Sleep this long, then proceed.
    Delay(Duration),
    /// Flip a bit in the encoded frame before writing it.
    Corrupt,
    /// Fail the connection attempt.
    Refuse,
    /// Kill the connection abruptly.
    Kill,
    /// Sleep this long (stalled shard), then proceed.
    Stall(Duration),
}

impl FaultAction {
    /// The sleep this action implies, if any — callers that only
    /// distinguish "wait" from "act" can collapse Delay/Stall here.
    pub fn sleep(self) -> Option<Duration> {
        match self {
            FaultAction::Delay(d) | FaultAction::Stall(d) => Some(d),
            _ => None,
        }
    }
}

/// One injected fault, for the bounded event log.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub site: FaultSite,
    pub shard: Option<u32>,
    /// The site-local sequence number of the decision.
    pub seq: u64,
    /// Index of the rule that fired.
    pub rule: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Stable one-line rendering (chaos-run logs, debugging).
    pub fn line(&self) -> String {
        match self.shard {
            Some(s) => format!(
                "fault {} seq={} shard={} rule={}",
                format_args!("{}@{}", self.kind.label(), self.site.label()),
                self.seq,
                s,
                self.rule
            ),
            None => format!(
                "fault {} seq={} rule={}",
                format_args!("{}@{}", self.kind.label(), self.site.label()),
                self.seq,
                self.rule
            ),
        }
    }
}

/// Bound on the in-memory fault event ring.
const EVENT_RING_CAP: usize = 4096;

/// A live, armed fault plan: per-site decision counters plus a bounded
/// event log. Cheap to share (`Arc`), cheap to consult (one atomic
/// fetch-add and a few hashes per decision; zero when the plan has no
/// rule for the site).
#[derive(Debug)]
pub struct Faults {
    plan: FaultPlan,
    scope: Option<u32>,
    enabled: AtomicBool,
    counters: [AtomicU64; 10],
    corrupt_seq: AtomicU64,
    injected: AtomicU64,
    events: Mutex<VecDeque<FaultEvent>>,
}

/// What call sites hold: `None` = fault injection compiled down to one
/// branch (the [`crate::obs::ObsConfig`] pattern).
pub type FaultHook = Option<Arc<Faults>>;

impl Faults {
    /// The plan this instance was armed from.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Disarm (or re-arm) injection at runtime — recovery phases of
    /// chaos tests flip this instead of rebuilding the fabric.
    pub fn set_enabled(&self, enabled: bool) {
        self.enabled.store(enabled, Ordering::SeqCst);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::SeqCst)
    }

    /// Total faults injected so far.
    pub fn injected_total(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Snapshot of the (bounded) event log, oldest first.
    pub fn events(&self) -> Vec<FaultEvent> {
        self.events.lock().unwrap().iter().cloned().collect()
    }

    /// The k-th decision at `site`: a pure function of
    /// `(seed, site, rules, k)`. `shard` scopes shard-targeted rules;
    /// an armed scope (shard workers) wins over the per-call value.
    pub fn decide(&self, site: FaultSite, shard: Option<u32>) -> FaultAction {
        if !self.enabled.load(Ordering::Relaxed) {
            return FaultAction::None;
        }
        let shard = self.scope.or(shard);
        let seq = self.counters[site.index()].fetch_add(1, Ordering::Relaxed);
        match decide_pure(&self.plan, site, shard, seq) {
            Some((rule, kind, action)) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                let mut events = self.events.lock().unwrap();
                if events.len() >= EVENT_RING_CAP {
                    events.pop_front();
                }
                events.push_back(FaultEvent { site, shard, seq, rule, kind });
                action
            }
            None => FaultAction::None,
        }
    }

    /// Flip one deterministic bit in an encoded frame's 4-byte magic.
    ///
    /// Live injection restricts itself to the magic on purpose: any flip
    /// there is a *guaranteed* prompt decode error at the receiving peer,
    /// so the fault stays error-shaped and the redial ladder owns it.
    /// Flipping deeper bytes can be silent (a payload value bit) or
    /// ambiguous (a tag aliasing to another message), which turns a chaos
    /// run into wrong answers instead of recoverable faults — the wire
    /// property tests cover those decode paths exhaustively without I/O,
    /// and the length field (offsets 8..12) separately, because a length
    /// flip blocks until the peer's I/O timeout (timing-shaped, not
    /// error-shaped).
    pub fn corrupt_frame(&self, frame: &mut [u8]) {
        if frame.is_empty() {
            return;
        }
        let seq = self.corrupt_seq.fetch_add(1, Ordering::Relaxed);
        let z = mix(self.plan.seed ^ 0xc0dec0de ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let span = frame.len().min(4);
        let pos = (z as usize) % span;
        let bit = ((z >> 32) % 8) as u8;
        frame[pos] ^= 1 << bit;
    }

    /// Flip one deterministic bit *anywhere* in `buf` — the snapshot
    /// analogue of [`Faults::corrupt_frame`]. Snapshots carry a CRC32
    /// trailer, so unlike the wire path a flip in any byte is detected
    /// on load; restricting the flip to a header is unnecessary here.
    pub fn corrupt_bytes(&self, buf: &mut [u8]) {
        if buf.is_empty() {
            return;
        }
        let seq = self.corrupt_seq.fetch_add(1, Ordering::Relaxed);
        let z = mix(self.plan.seed ^ 0x5eedfa11 ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let pos = (z as usize) % buf.len();
        let bit = ((z >> 32) % 8) as u8;
        buf[pos] ^= 1 << bit;
    }
}

/// splitmix64 finalizer — the hash behind every decision.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Uniform in `[0, 1)` from `(seed, site, rule, seq)`.
fn unit(seed: u64, site: FaultSite, rule: usize, seq: u64) -> f64 {
    let z = mix(
        seed ^ site.salt()
            ^ ((rule as u64 + 1) << 48)
            ^ seq.wrapping_mul(0x2545_f491_4f6c_dd1d),
    );
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// The pure decision core shared by [`Faults::decide`] and
/// [`schedule_digest`]: first matching rule wins.
fn decide_pure(
    plan: &FaultPlan,
    site: FaultSite,
    shard: Option<u32>,
    seq: u64,
) -> Option<(usize, FaultKind, FaultAction)> {
    for (i, rule) in plan.rules.iter().enumerate() {
        if rule.site != site {
            continue;
        }
        if let Some(want) = rule.shard {
            if shard != Some(want) {
                continue;
            }
        }
        if unit(plan.seed, site, i, seq) < rule.prob {
            let action = match rule.kind {
                FaultKind::Drop => FaultAction::Drop,
                FaultKind::Delay => {
                    FaultAction::Delay(Duration::from_millis(rule.millis))
                }
                FaultKind::Corrupt => FaultAction::Corrupt,
                FaultKind::Refuse => FaultAction::Refuse,
                FaultKind::Kill => FaultAction::Kill,
                FaultKind::Stall => {
                    FaultAction::Stall(Duration::from_millis(rule.millis))
                }
            };
            return Some((i, rule.kind, action));
        }
    }
    None
}

/// Fold the first `n` decisions of every site (unscoped) into one hash.
/// Depends only on `(seed, rules)` — two runs of the same plan print
/// the same digest, which is the CI reproducibility assertion.
pub fn schedule_digest(plan: &FaultPlan, n: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut fold = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    for site in FaultSite::ALL {
        for seq in 0..n {
            // Probe both the unscoped stream and each scoped shard the
            // plan names, so shard-targeted rules shape the digest too.
            let mut scopes: Vec<Option<u32>> = vec![None];
            for rule in &plan.rules {
                if let Some(s) = rule.shard {
                    if !scopes.contains(&Some(s)) {
                        scopes.push(Some(s));
                    }
                }
            }
            for scope in scopes {
                if let Some((rule, kind, _)) = decide_pure(plan, site, scope, seq) {
                    fold(site.index() as u64 + 1);
                    fold(scope.map_or(u64::MAX, u64::from));
                    fold(seq);
                    fold(rule as u64);
                    fold(kind as u64 + 1);
                }
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_round_trips() {
        let spec = "seed=42,delay=0.2x5ms@serve/shard0,corrupt=0.05@shard_send,kill=0.02";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.rules.len(), 3);
        assert_eq!(plan.rules[0].kind, FaultKind::Delay);
        assert_eq!(plan.rules[0].millis, 5);
        assert_eq!(plan.rules[0].shard, Some(0));
        assert_eq!(plan.rules[0].site, FaultSite::Serve);
        // kill with no site lands at its natural site.
        assert_eq!(plan.rules[2].site, FaultSite::ShardSend);
        // Display → parse is the identity.
        let reparsed = FaultPlan::parse(&plan.to_string()).unwrap();
        assert_eq!(reparsed, plan);
    }

    #[test]
    fn bad_specs_fail_fast() {
        for bad in [
            "frob=0.5",
            "delay=2.0",
            "delay=0.5@nowhere",
            "seed=notanumber",
            "delay",
            "delay=0.5x10s",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
        // Empty items are tolerated (trailing commas).
        assert!(FaultPlan::parse("seed=1,").unwrap().is_empty());
    }

    #[test]
    fn same_seed_same_decisions() {
        let plan = FaultPlan::parse("seed=7,drop=0.3@shard_send,delay=0.4x1ms@serve")
            .unwrap();
        let a = plan.arm(None);
        let b = plan.arm(None);
        let mut injected = 0;
        for _ in 0..512 {
            let da = a.decide(FaultSite::ShardSend, None);
            let db = b.decide(FaultSite::ShardSend, None);
            assert_eq!(da, db);
            if da != FaultAction::None {
                injected += 1;
            }
            assert_eq!(
                a.decide(FaultSite::Serve, None),
                b.decide(FaultSite::Serve, None)
            );
        }
        // ~30% of 512 — loose bounds, deterministic given the seed.
        assert!(injected > 100 && injected < 220, "injected {injected}");
        assert_eq!(a.events(), b.events());
    }

    #[test]
    fn different_seed_diverges() {
        let rules = "drop=0.3@shard_send";
        let a = FaultPlan::parse(&format!("seed=1,{rules}")).unwrap().arm(None);
        let b = FaultPlan::parse(&format!("seed=2,{rules}")).unwrap().arm(None);
        let diverged = (0..256).any(|_| {
            a.decide(FaultSite::ShardSend, None) != b.decide(FaultSite::ShardSend, None)
        });
        assert!(diverged);
    }

    #[test]
    fn probability_extremes() {
        let always = FaultPlan::parse("seed=3,refuse=1.0@connect").unwrap().arm(None);
        let never = FaultPlan::parse("seed=3,refuse=0.0@connect").unwrap().arm(None);
        for _ in 0..64 {
            assert_eq!(always.decide(FaultSite::Connect, None), FaultAction::Refuse);
            assert_eq!(never.decide(FaultSite::Connect, None), FaultAction::None);
        }
        assert_eq!(always.injected_total(), 64);
        assert_eq!(never.injected_total(), 0);
    }

    #[test]
    fn shard_scope_filters() {
        let plan = FaultPlan::parse("seed=5,refuse=1.0@connect/shard1").unwrap();
        let f = plan.arm(None);
        assert_eq!(f.decide(FaultSite::Connect, Some(0)), FaultAction::None);
        assert_eq!(f.decide(FaultSite::Connect, Some(1)), FaultAction::Refuse);
        assert_eq!(f.decide(FaultSite::Connect, None), FaultAction::None);
        // An armed scope (a shard worker's own id) wins.
        let scoped = plan.arm(Some(1));
        assert_eq!(scoped.decide(FaultSite::Connect, None), FaultAction::Refuse);
    }

    #[test]
    fn disarm_stops_injection() {
        let f = FaultPlan::parse("seed=9,refuse=1.0@connect").unwrap().arm(None);
        assert_eq!(f.decide(FaultSite::Connect, None), FaultAction::Refuse);
        f.set_enabled(false);
        assert!(!f.enabled());
        assert_eq!(f.decide(FaultSite::Connect, None), FaultAction::None);
        f.set_enabled(true);
        assert_ne!(f.decide(FaultSite::Connect, None), FaultAction::None);
    }

    #[test]
    fn first_matching_rule_wins() {
        let plan =
            FaultPlan::parse("seed=11,kill=1.0@shard_send,drop=1.0@shard_send").unwrap();
        let f = plan.arm(None);
        assert_eq!(f.decide(FaultSite::ShardSend, None), FaultAction::Kill);
    }

    #[test]
    fn digest_is_pure_and_seed_sensitive() {
        let plan = FaultPlan::parse("seed=42,drop=0.3@shard_send,delay=0.1x2ms@serve")
            .unwrap();
        let d1 = schedule_digest(&plan, 64);
        let d2 = schedule_digest(&plan, 64);
        assert_eq!(d1, d2);
        let other = FaultPlan { seed: 43, ..plan.clone() };
        assert_ne!(d1, schedule_digest(&other, 64));
        // Arming and deciding does not perturb the digest (pure fn).
        let f = plan.arm(None);
        for _ in 0..32 {
            f.decide(FaultSite::ShardSend, None);
        }
        assert_eq!(schedule_digest(&plan, 64), d1);
    }

    #[test]
    fn corrupt_frame_is_deterministic_and_stays_in_the_magic() {
        let base = vec![0u8; 64];
        let a = FaultPlan::seeded(17).arm(None);
        let b = FaultPlan::seeded(17).arm(None);
        for _ in 0..32 {
            let mut fa = base.clone();
            let mut fb = base.clone();
            a.corrupt_frame(&mut fa);
            b.corrupt_frame(&mut fb);
            assert_eq!(fa, fb);
            let flipped: Vec<usize> =
                (0..fa.len()).filter(|&i| fa[i] != base[i]).collect();
            assert_eq!(flipped.len(), 1, "exactly one byte flips");
            assert!(
                flipped[0] < 4,
                "live corruption must stay in the magic so it is always \
                 detected (flipped {})",
                flipped[0]
            );
        }
    }

    #[test]
    fn learning_sites_parse_and_round_trip() {
        let spec = "seed=77,corrupt=0.2@corrupt_row,kill=1.0@truncate_model,\
                    delay=0.5x2ms@slow_counts,kill=0.3@learn_kill";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.rules.len(), 4);
        assert_eq!(plan.rules[0].site, FaultSite::CorruptRow);
        assert_eq!(plan.rules[1].site, FaultSite::TruncateModel);
        assert_eq!(plan.rules[2].site, FaultSite::SlowCounts);
        assert_eq!(plan.rules[3].site, FaultSite::LearnKill);
        let reparsed = FaultPlan::parse(&plan.to_string()).unwrap();
        assert_eq!(reparsed, plan);
        // Labels are stable and distinct across all ten sites.
        let mut labels: Vec<&str> = FaultSite::ALL.iter().map(|s| s.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 10);
        // Learning sites have their own decision streams and show up in
        // the schedule digest like any wire site.
        let d = schedule_digest(&plan, 32);
        assert_eq!(d, schedule_digest(&plan, 32));
        let always = FaultPlan::parse("seed=1,kill=1.0@learn_kill").unwrap().arm(None);
        assert_eq!(always.decide(FaultSite::LearnKill, None), FaultAction::Kill);
        assert_eq!(always.injected_total(), 1);
    }

    #[test]
    fn corrupt_bytes_is_deterministic_single_bit() {
        let base = vec![0u8; 256];
        let a = FaultPlan::seeded(17).arm(None);
        let b = FaultPlan::seeded(17).arm(None);
        for _ in 0..32 {
            let mut fa = base.clone();
            let mut fb = base.clone();
            a.corrupt_bytes(&mut fa);
            b.corrupt_bytes(&mut fb);
            assert_eq!(fa, fb);
            let flipped: Vec<usize> =
                (0..fa.len()).filter(|&i| fa[i] != base[i]).collect();
            assert_eq!(flipped.len(), 1, "exactly one byte flips");
            assert_eq!((fa[flipped[0]] ^ base[flipped[0]]).count_ones(), 1);
        }
    }

    #[test]
    fn event_lines_render() {
        let f = FaultPlan::parse("seed=1,refuse=1.0@connect/shard2").unwrap().arm(None);
        f.decide(FaultSite::Connect, Some(2));
        let events = f.events();
        assert_eq!(events.len(), 1);
        assert!(events[0].line().contains("refuse@connect"));
        assert!(events[0].line().contains("shard=2"));
    }
}
