//! Parallel execution substrate — the paper's optimization (i).
//!
//! The original Fast-PGM parallelizes with OpenMP; its contribution is the
//! *scheduling policy*: a **dynamic work pool** in which workers pull the
//! next unit of work (a CI test, a clique update, a chunk of samples) as
//! soon as they finish the previous one, so irregular task costs — the norm
//! in PGM workloads — never leave cores idle.
//!
//! The offline build image carries no `rayon`/`tokio`, so the pool is
//! implemented directly on `std::thread`:
//!
//! * [`parallel_for_dynamic`] — scoped fork-join over an index range with an
//!   atomic cursor (equivalent to `omp parallel for schedule(dynamic,
//!   chunk)`); this powers CI-level, clique-level and sample-level
//!   parallelism.
//! * [`WorkPool`] — a persistent pool with a shared FIFO queue for
//!   long-lived components (the serving coordinator).

mod pool;

pub use pool::WorkPool;

use std::sync::atomic::{AtomicUsize, Ordering};

/// A raw pointer wrapper that is `Send + Sync`, for fan-out kernels whose
/// workers write provably disjoint regions of one buffer (span-split table
/// scans, per-worker partial reductions). The *user* carries the safety
/// obligation: every dereference must stay inside the caller's disjoint
/// region for the duration of the parallel scope.
pub struct SyncPtr<T>(pub *mut T);

unsafe impl<T: Send> Sync for SyncPtr<T> {}
unsafe impl<T: Send> Send for SyncPtr<T> {}

/// Number of worker threads to default to (physical parallelism of the
/// container, capped to keep benches stable).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Dynamic-scheduling parallel for: `body(i)` is called exactly once for
/// every `i in 0..n`, from `threads` workers that claim `chunk`-sized spans
/// off a shared atomic cursor. `body` must be `Sync` (it is shared by
/// reference) — use interior mutability or per-index output slots.
///
/// With `threads <= 1` the loop runs inline, which keeps sequential
/// baselines honest (no pool overhead in the "1 thread" bench rows).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, chunk: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let chunk = chunk.max(1);
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let workers = threads.min(n.div_ceil(chunk));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(i);
                }
            });
        }
    });
}

/// Map `0..n` in parallel into a `Vec<T>`, preserving index order.
/// Implemented over [`parallel_for_dynamic`] with per-slot writes.
pub fn parallel_map<T, F>(n: usize, threads: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::cell::UnsafeCell;
    struct Slots<T>(UnsafeCell<Vec<Option<T>>>);
    // SAFETY: each index is written by exactly one worker (disjoint spans
    // claimed from the atomic cursor) and read only after the scope joins.
    unsafe impl<T: Send> Sync for Slots<T> {}

    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let slots = Slots(UnsafeCell::new(out));
    let slots_ref = &slots; // capture the Sync wrapper, not its field
    parallel_for_dynamic(n, threads, chunk, move |i| {
        let v = f(i);
        unsafe {
            let vec: &mut Vec<Option<T>> = &mut *slots_ref.0.get();
            vec[i] = Some(v);
        }
    });
    slots
        .0
        .into_inner()
        .into_iter()
        .map(|x| x.expect("parallel_map slot unfilled"))
        .collect()
}

/// Split `n` items into per-thread spans and reduce each span with `map`,
/// then fold the partials with `reduce`. Static partition — used when the
/// per-item cost is uniform (e.g. streaming dataset columns) and chunk
/// claiming overhead would dominate.
pub fn parallel_reduce<T, M, R>(n: usize, threads: usize, map: M, reduce: R) -> Option<T>
where
    T: Send,
    M: Fn(std::ops::Range<usize>) -> T + Sync,
    R: Fn(T, T) -> T,
{
    if n == 0 {
        return None;
    }
    if threads <= 1 {
        return Some(map(0..n));
    }
    let workers = threads.min(n);
    let span = n.div_ceil(workers);
    let partials: Vec<T> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let map = &map;
                let lo = w * span;
                let hi = ((w + 1) * span).min(n);
                scope.spawn(move || map(lo..hi))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    partials.into_iter().reduce(reduce)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn for_dynamic_covers_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_dynamic(n, 4, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn for_dynamic_single_thread_inline() {
        let sum = AtomicU64::new(0);
        parallel_for_dynamic(100, 1, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(257, 4, 16, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn map_zero_len() {
        let out: Vec<usize> = parallel_map(0, 4, 16, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn reduce_sums() {
        let total =
            parallel_reduce(10_000, 4, |r| r.map(|i| i as u64).sum::<u64>(), |a, b| a + b);
        assert_eq!(total, Some(49_995_000));
        assert_eq!(
            parallel_reduce(10_000, 1, |r| r.map(|i| i as u64).sum::<u64>(), |a, b| a + b),
            Some(49_995_000)
        );
    }

    #[test]
    fn reduce_empty_none() {
        assert_eq!(parallel_reduce::<u64, _, _>(0, 4, |_| 0, |a, b| a + b), None);
    }

    #[test]
    fn irregular_workload_balanced() {
        // Tasks with wildly different costs still all complete.
        let done = AtomicUsize::new(0);
        parallel_for_dynamic(64, 4, 1, |i| {
            let mut x = 0u64;
            for k in 0..(i as u64 * 1000) {
                x = x.wrapping_add(k);
            }
            std::hint::black_box(x);
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 64);
    }
}
