//! Persistent dynamic work pool.
//!
//! Long-lived components (the serving coordinator, background bench
//! drivers) need a pool that outlives any one scope. `WorkPool` keeps `N`
//! workers parked on a condvar over a FIFO of boxed jobs and exposes
//! `execute` + `wait_idle`. The *dynamic* part is inherent: workers pull
//! jobs as they free up, so heterogeneous job costs balance automatically —
//! the behaviour the paper's "dynamic work pool [to] monitor processing and
//! schedule workloads" describes.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<State>,
    /// Signals workers that a job arrived or shutdown began.
    work_cv: Condvar,
    /// Signals waiters that the pool may have drained.
    idle_cv: Condvar,
}

struct State {
    jobs: VecDeque<Job>,
    in_flight: usize,
    shutdown: bool,
}

/// Fixed-size thread pool with a shared dynamic queue.
pub struct WorkPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkPool {
    /// Spawn a pool with `threads` workers (at least 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State {
                jobs: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fastpgm-pool-{w}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkPool { shared, workers }
    }

    fn worker_loop(shared: &Shared) {
        loop {
            let job = {
                let mut st = shared.queue.lock().unwrap();
                loop {
                    if let Some(job) = st.jobs.pop_front() {
                        st.in_flight += 1;
                        break job;
                    }
                    if st.shutdown {
                        return;
                    }
                    st = shared.work_cv.wait(st).unwrap();
                }
            };
            job();
            let mut st = shared.queue.lock().unwrap();
            st.in_flight -= 1;
            if st.in_flight == 0 && st.jobs.is_empty() {
                shared.idle_cv.notify_all();
            }
        }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job; it runs as soon as a worker is free.
    pub fn execute<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'static,
    {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "execute after shutdown");
        st.jobs.push_back(Box::new(job));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        while st.in_flight > 0 || !st.jobs.is_empty() {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Pending + running job count (approximate, for metrics).
    pub fn load(&self) -> usize {
        let st = self.shared.queue.lock().unwrap();
        st.jobs.len() + st.in_flight
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = WorkPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&count);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = WorkPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn jobs_drain_on_drop() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkPool::new(2);
            for _ in 0..16 {
                let c = Arc::clone(&count);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
        }
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn uneven_jobs_all_complete() {
        let pool = WorkPool::new(3);
        let total = Arc::new(AtomicUsize::new(0));
        for i in 0..32 {
            let t = Arc::clone(&total);
            pool.execute(move || {
                if i % 7 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                t.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(total.load(Ordering::Relaxed), (0..32).sum::<usize>());
        assert_eq!(pool.load(), 0);
    }
}
