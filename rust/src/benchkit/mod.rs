//! Minimal benchmark harness (the offline image has no criterion crate).
//!
//! Each `benches/*.rs` target is a plain `harness = false` binary built on
//! this module: warmup runs, then `samples` timed runs, reporting
//! min/median/p95 wall-clock. Good enough to regenerate the *shape* of the
//! paper's tables — who wins and by what factor — which is what
//! EXPERIMENTS.md records.

use std::time::{Duration, Instant};

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        s[s.len() / 2]
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().copied().min().unwrap_or_default()
    }

    pub fn p95(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        let i = ((s.len() as f64 * 0.95) as usize).min(s.len() - 1);
        s[i]
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `samples` measured
/// runs. The closure's return value is black-boxed to keep the optimizer
/// honest.
pub fn bench<T>(
    label: impl Into<String>,
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(t0.elapsed());
    }
    Measurement { label: label.into(), samples: out }
}

/// Pretty-print a table of measurements with a speedup column relative to
/// the first row.
pub fn report(title: &str, rows: &[Measurement]) {
    println!("\n== {title} ==");
    let base = rows.first().map(|m| m.median().as_secs_f64());
    println!("{:<44} {:>12} {:>12} {:>9}", "case", "median", "min", "speedup");
    for m in rows {
        let med = m.median().as_secs_f64();
        let speedup = base.map(|b| b / med).unwrap_or(1.0);
        println!(
            "{:<44} {:>12} {:>12} {:>8.2}x",
            m.label,
            fmt_duration(m.median()),
            fmt_duration(m.min()),
            speedup
        );
    }
}

/// Human duration formatting (µs → s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Throughput helper: items per second from a measured median.
pub fn throughput(items: usize, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let m = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.min() <= m.median());
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn throughput_sane() {
        let t = throughput(1000, Duration::from_secs(2));
        assert!((t - 500.0).abs() < 1e-9);
    }
}
