//! Minimal benchmark harness (the offline image has no criterion crate).
//!
//! Each `benches/*.rs` target is a plain `harness = false` binary built on
//! this module: warmup runs, then `samples` timed runs, reporting
//! min/median/p95 wall-clock. Good enough to regenerate the *shape* of the
//! paper's tables — who wins and by what factor — which is what
//! EXPERIMENTS.md records.

use std::time::{Duration, Instant};

/// Quick mode: set `FASTPGM_BENCH_QUICK=1` (any non-empty value except
/// `0`) to make bench binaries shrink their sample counts and workloads —
/// the CI smoke-run setting, where the point is to exercise the bench and
/// emit its `BENCH_*.json` artifact, not to produce stable medians.
pub fn quick() -> bool {
    std::env::var("FASTPGM_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// `full` normally, `quick` under [`quick`] mode — for scaling workload
/// constants in one expression.
pub fn scaled(full: usize, quick_value: usize) -> usize {
    if quick() {
        quick_value
    } else {
        full
    }
}

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        s[s.len() / 2]
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().copied().min().unwrap_or_default()
    }

    /// Arithmetic mean of the samples (zero when empty).
    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    pub fn p95(&self) -> Duration {
        self.percentile(95.0)
    }

    /// Arbitrary percentile (p in [0, 100]) over the samples.
    pub fn percentile(&self, p: f64) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        let i = ((s.len() as f64 * p / 100.0) as usize).min(s.len() - 1);
        s[i]
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `samples` measured
/// runs. The closure's return value is black-boxed to keep the optimizer
/// honest.
pub fn bench<T>(
    label: impl Into<String>,
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(t0.elapsed());
    }
    Measurement { label: label.into(), samples: out }
}

/// Pretty-print a table of measurements with a speedup column relative to
/// the first row.
pub fn report(title: &str, rows: &[Measurement]) {
    println!("\n== {title} ==");
    let base = rows.first().map(|m| m.median().as_secs_f64());
    println!("{:<44} {:>12} {:>12} {:>9}", "case", "median", "min", "speedup");
    for m in rows {
        let med = m.median().as_secs_f64();
        let speedup = base.map(|b| b / med).unwrap_or(1.0);
        println!(
            "{:<44} {:>12} {:>12} {:>8.2}x",
            m.label,
            fmt_duration(m.median()),
            fmt_duration(m.min()),
            speedup
        );
    }
}

/// Human duration formatting (µs → s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Throughput helper: items per second from a measured median.
pub fn throughput(items: usize, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

/// Minimal JSON emission for the `BENCH_*.json` perf-trajectory files (the
/// offline image carries no serde). Values are built as trees of
/// [`json::Json`] and serialized with [`json::write`]; numbers that are
/// not finite serialize as `null` so downstream tooling never sees `NaN`.
pub mod json {
    use std::fmt;
    use std::io::Write as _;
    use std::path::Path;

    /// A JSON value.
    #[derive(Clone, Debug)]
    pub enum Json {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Json>),
        /// Insertion-ordered object.
        Obj(Vec<(String, Json)>),
    }

    impl Json {
        pub fn num(x: f64) -> Json {
            Json::Num(x)
        }

        pub fn str(s: impl Into<String>) -> Json {
            Json::Str(s.into())
        }

        pub fn obj<I, K>(pairs: I) -> Json
        where
            I: IntoIterator<Item = (K, Json)>,
            K: Into<String>,
        {
            Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
        }
    }

    fn escape(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("\"")?;
        for c in s.chars() {
            match c {
                '"' => f.write_str("\\\"")?,
                '\\' => f.write_str("\\\\")?,
                '\n' => f.write_str("\\n")?,
                '\t' => f.write_str("\\t")?,
                '\r' => f.write_str("\\r")?,
                c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                c => write!(f, "{c}")?,
            }
        }
        f.write_str("\"")
    }

    impl fmt::Display for Json {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            match self {
                Json::Null => f.write_str("null"),
                Json::Bool(b) => write!(f, "{b}"),
                Json::Num(x) if x.is_finite() => write!(f, "{x}"),
                Json::Num(_) => f.write_str("null"),
                Json::Str(s) => escape(s, f),
                Json::Arr(items) => {
                    f.write_str("[")?;
                    for (i, v) in items.iter().enumerate() {
                        if i > 0 {
                            f.write_str(",")?;
                        }
                        write!(f, "{v}")?;
                    }
                    f.write_str("]")
                }
                Json::Obj(pairs) => {
                    f.write_str("{")?;
                    for (i, (k, v)) in pairs.iter().enumerate() {
                        if i > 0 {
                            f.write_str(",")?;
                        }
                        escape(k, f)?;
                        f.write_str(":")?;
                        write!(f, "{v}")?;
                    }
                    f.write_str("}")
                }
            }
        }
    }

    /// Write a value to `path` with a trailing newline.
    pub fn write(path: &Path, value: &Json) -> std::io::Result<()> {
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{value}")
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn serializes_nested() {
            let v = Json::obj([
                ("name", Json::str("serving")),
                ("qps", Json::num(1234.5)),
                ("nan", Json::num(f64::NAN)),
                ("rows", Json::Arr(vec![Json::num(1.0), Json::Bool(true), Json::Null])),
            ]);
            assert_eq!(
                v.to_string(),
                r#"{"name":"serving","qps":1234.5,"nan":null,"rows":[1,true,null]}"#
            );
        }

        #[test]
        fn escapes_strings() {
            let v = Json::str("a\"b\\c\nd");
            assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\"");
        }

        #[test]
        fn writes_file() {
            let path = std::env::temp_dir().join("fastpgm_benchkit_json_test.json");
            write(&path, &Json::obj([("ok", Json::Bool(true))])).unwrap();
            let text = std::fs::read_to_string(&path).unwrap();
            assert_eq!(text.trim(), r#"{"ok":true}"#);
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let m = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.min() <= m.median());
        assert!(m.min() <= m.mean());
        let empty = Measurement { label: "e".into(), samples: Vec::new() };
        assert_eq!(empty.mean(), Duration::ZERO);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn throughput_sane() {
        let t = throughput(1000, Duration::from_secs(2));
        assert!((t - 500.0).abs() < 1e-9);
    }
}
