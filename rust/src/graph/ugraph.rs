//! Undirected graphs: PC skeletons, moral graphs, triangulated graphs.

use crate::core::VarId;

/// Undirected graph with sorted adjacency lists.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct UGraph {
    adj: Vec<Vec<VarId>>,
}

impl UGraph {
    pub fn new(n: usize) -> Self {
        UGraph { adj: vec![Vec::new(); n] }
    }

    /// Complete graph over `n` nodes — the PC algorithm's starting point.
    pub fn complete(n: usize) -> Self {
        let mut g = UGraph::new(n);
        for a in 0..n {
            g.adj[a] = (0..n).filter(|&b| b != a).collect();
        }
        g
    }

    pub fn n_nodes(&self) -> usize {
        self.adj.len()
    }

    pub fn n_edges(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    #[inline]
    pub fn neighbors(&self, v: VarId) -> &[VarId] {
        &self.adj[v]
    }

    pub fn degree(&self, v: VarId) -> usize {
        self.adj[v].len()
    }

    #[inline]
    pub fn has_edge(&self, a: VarId, b: VarId) -> bool {
        self.adj[a].binary_search(&b).is_ok()
    }

    pub fn add_edge(&mut self, a: VarId, b: VarId) {
        assert!(a != b, "self loop");
        if let Err(i) = self.adj[a].binary_search(&b) {
            self.adj[a].insert(i, b);
            let j = self.adj[b].binary_search(&a).unwrap_err();
            self.adj[b].insert(j, a);
        }
    }

    pub fn remove_edge(&mut self, a: VarId, b: VarId) {
        if let Ok(i) = self.adj[a].binary_search(&b) {
            self.adj[a].remove(i);
            let j = self.adj[b].binary_search(&a).unwrap();
            self.adj[b].remove(j);
        }
    }

    /// Edges as `(a, b)` with `a < b`, sorted.
    pub fn edges(&self) -> Vec<(VarId, VarId)> {
        let mut es = Vec::with_capacity(self.n_edges());
        for a in 0..self.n_nodes() {
            for &b in &self.adj[a] {
                if a < b {
                    es.push((a, b));
                }
            }
        }
        es
    }

    /// Do the given nodes form a clique?
    pub fn is_clique(&self, nodes: &[VarId]) -> bool {
        for (i, &a) in nodes.iter().enumerate() {
            for &b in &nodes[i + 1..] {
                if !self.has_edge(a, b) {
                    return false;
                }
            }
        }
        true
    }

    /// Connect every pair in `nodes` (fill-in during triangulation).
    pub fn make_clique(&mut self, nodes: &[VarId]) {
        for (i, &a) in nodes.iter().enumerate() {
            for &b in &nodes[i + 1..] {
                self.add_edge(a, b);
            }
        }
    }

    /// Connected components, each sorted; components sorted by minimum node.
    pub fn components(&self) -> Vec<Vec<VarId>> {
        let n = self.n_nodes();
        let mut seen = vec![false; n];
        let mut comps = Vec::new();
        for s in 0..n {
            if seen[s] {
                continue;
            }
            let mut comp = vec![s];
            seen[s] = true;
            let mut stack = vec![s];
            while let Some(v) = stack.pop() {
                for &w in &self.adj[v] {
                    if !seen[w] {
                        seen[w] = true;
                        comp.push(w);
                        stack.push(w);
                    }
                }
            }
            comp.sort_unstable();
            comps.push(comp);
        }
        comps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_graph_edge_count() {
        let g = UGraph::complete(5);
        assert_eq!(g.n_edges(), 10);
        assert!(g.has_edge(0, 4));
        assert_eq!(g.degree(2), 4);
    }

    #[test]
    fn add_remove_symmetric() {
        let mut g = UGraph::new(3);
        g.add_edge(0, 2);
        assert!(g.has_edge(2, 0));
        g.add_edge(0, 2); // idempotent
        assert_eq!(g.n_edges(), 1);
        g.remove_edge(2, 0);
        assert_eq!(g.n_edges(), 0);
    }

    #[test]
    fn clique_ops() {
        let mut g = UGraph::new(4);
        g.make_clique(&[0, 1, 3]);
        assert!(g.is_clique(&[0, 1, 3]));
        assert!(!g.is_clique(&[0, 1, 2]));
        assert_eq!(g.n_edges(), 3);
    }

    #[test]
    fn components_found() {
        let mut g = UGraph::new(5);
        g.add_edge(0, 1);
        g.add_edge(3, 4);
        let comps = g.components();
        assert_eq!(comps, vec![vec![0, 1], vec![2], vec![3, 4]]);
    }

    #[test]
    fn edges_sorted_unique() {
        let mut g = UGraph::new(4);
        g.add_edge(2, 1);
        g.add_edge(0, 3);
        assert_eq!(g.edges(), vec![(0, 3), (1, 2)]);
    }
}
