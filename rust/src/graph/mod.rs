//! Graph substrates: DAGs (Bayesian-network structure), partially directed
//! graphs (PC-stable output), and undirected graphs (skeletons, moral
//! graphs, triangulation).

mod dag;
mod dsep;
mod pdag;
mod ugraph;

pub use dag::Dag;
pub use dsep::{d_connected_set, d_separated};
pub use pdag::{EdgeMark, Pdag};
pub use ugraph::UGraph;
