//! d-separation queries on DAGs (Pearl 1988) — the graphical criterion
//! conditional-independence tests estimate from data. Used by the test
//! suite as the ground truth oracle for PC's recovered independencies and
//! exposed as a library feature (`fastpgm::graph::d_separated`).

use crate::core::VarId;
use super::Dag;

/// Is `x` d-separated from `y` given the conditioning set `z`?
///
/// Implemented with the reachability formulation (Koller & Friedman,
/// "Reachable" / Bayes-ball): a path is active while successive triples
/// are active; colliders are active iff the collider or one of its
/// descendants is in `z`.
pub fn d_separated(dag: &Dag, x: VarId, y: VarId, z: &[VarId]) -> bool {
    if x == y {
        return false;
    }
    let n = dag.n_nodes();
    let in_z = {
        let mut b = vec![false; n];
        for &v in z {
            b[v] = true;
        }
        b
    };
    // Ancestors of z (needed for collider activation).
    let mut z_anc = in_z.clone();
    {
        let mut stack: Vec<VarId> = z.to_vec();
        while let Some(v) = stack.pop() {
            for &p in dag.parents(v) {
                if !z_anc[p] {
                    z_anc[p] = true;
                    stack.push(p);
                }
            }
        }
    }

    // Bayes-ball: states are (node, direction) where direction is how we
    // arrived: `true` = via an edge pointing *into* the node (from a
    // parent), `false` = via an edge leaving the node (from a child).
    let mut visited = vec![[false; 2]; n];
    // Start from x as if we came "from a child" (can go anywhere).
    let mut stack: Vec<(VarId, bool)> = vec![(x, false)];
    while let Some((v, from_parent)) = stack.pop() {
        let dir = usize::from(from_parent);
        if visited[v][dir] {
            continue;
        }
        visited[v][dir] = true;
        if v == y {
            return false; // active path found
        }
        if !from_parent {
            // Arrived from a child (or start): if v not observed, pass to
            // parents (chain against the edge) and to children.
            if !in_z[v] {
                for &p in dag.parents(v) {
                    stack.push((p, false));
                }
                for &c in dag.children(v) {
                    stack.push((c, true));
                }
            }
        } else {
            // Arrived from a parent.
            if !in_z[v] {
                // Chain: continue to children.
                for &c in dag.children(v) {
                    stack.push((c, true));
                }
            }
            if z_anc[v] {
                // Collider active (v in z or has descendant in z): bounce
                // back up to parents.
                for &p in dag.parents(v) {
                    stack.push((p, false));
                }
            }
        }
    }
    true
}

/// All variables d-connected to `x` given `z` (diagnostic helper).
pub fn d_connected_set(dag: &Dag, x: VarId, z: &[VarId]) -> Vec<VarId> {
    (0..dag.n_nodes())
        .filter(|&y| y != x && !d_separated(dag, x, y, z))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0 -> 1 -> 2 (chain), 3 -> 1 (extra parent), 1 -> 4.
    fn chain() -> Dag {
        let mut d = Dag::new(5);
        d.add_edge(0, 1);
        d.add_edge(1, 2);
        d.add_edge(3, 1);
        d.add_edge(1, 4);
        d
    }

    #[test]
    fn chain_blocked_by_mediator() {
        let d = chain();
        assert!(!d_separated(&d, 0, 2, &[]));
        assert!(d_separated(&d, 0, 2, &[1]));
    }

    #[test]
    fn fork_blocked_by_root() {
        // 2 <- 1 -> 4: common cause 1.
        let d = chain();
        assert!(!d_separated(&d, 2, 4, &[]));
        assert!(d_separated(&d, 2, 4, &[1]));
    }

    #[test]
    fn collider_inverts() {
        // 0 -> 1 <- 3: marginally independent, dependent given 1 or a
        // descendant of 1.
        let d = chain();
        assert!(d_separated(&d, 0, 3, &[]));
        assert!(!d_separated(&d, 0, 3, &[1]));
        assert!(!d_separated(&d, 0, 3, &[2]), "descendant of collider activates");
        assert!(!d_separated(&d, 0, 3, &[4]));
    }

    #[test]
    fn asia_known_independencies() {
        let net = crate::network::repository::asia();
        let d = net.dag();
        let idx = |n: &str| net.var_index(n).unwrap();
        // asia ⟂ smoke
        assert!(d_separated(d, idx("asia"), idx("smoke"), &[]));
        // asia ⟂̸ dysp (path through tub, either)
        assert!(!d_separated(d, idx("asia"), idx("dysp"), &[]));
        // asia ⟂ dysp | either, bronc
        assert!(d_separated(d, idx("asia"), idx("dysp"), &[idx("either"), idx("bronc")]));
        // tub ⟂ lung, but tub ⟂̸ lung | either (collider)
        assert!(d_separated(d, idx("tub"), idx("lung"), &[]));
        assert!(!d_separated(d, idx("tub"), idx("lung"), &[idx("either")]));
        // xray ⟂ smoke | either... path xray<-either<-lung<-smoke blocked
        assert!(d_separated(d, idx("xray"), idx("smoke"), &[idx("either")]));
    }

    #[test]
    fn d_connected_set_sane() {
        let d = chain();
        let conn = d_connected_set(&d, 0, &[]);
        assert!(conn.contains(&1) && conn.contains(&2) && conn.contains(&4));
        assert!(!conn.contains(&3));
    }
}
