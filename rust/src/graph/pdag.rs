//! Partially directed graphs — the output space of constraint-based
//! structure learning (CPDAGs) and the working representation during edge
//! orientation (v-structures + Meek rules).

use crate::core::VarId;
use super::{Dag, UGraph};

/// Mark of an edge incident to a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeMark {
    /// `a — b` undirected.
    Undirected,
    /// `a -> b` directed out of `a`.
    Directed,
}

/// A graph whose edges are each either directed or undirected.
///
/// Internally a dense pair-matrix of edge states — PC runs on at most a few
/// hundred nodes, where O(n²) bytes is trivially small and constant-time
/// edge updates matter (the orientation phase flips marks frequently).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pdag {
    n: usize,
    /// state[a*n+b]: 0 = none, 1 = a->b, 2 = a—b (mirrored as 2 in [b,a]).
    state: Vec<u8>,
}

const NONE: u8 = 0;
const DIR: u8 = 1; // row -> col
const UND: u8 = 2;

impl Pdag {
    pub fn new(n: usize) -> Self {
        Pdag { n, state: vec![NONE; n * n] }
    }

    /// Start from an undirected skeleton.
    pub fn from_skeleton(g: &UGraph) -> Self {
        let mut p = Pdag::new(g.n_nodes());
        for (a, b) in g.edges() {
            p.set_undirected(a, b);
        }
        p
    }

    /// View a DAG as a fully directed PDAG.
    pub fn from_dag(d: &Dag) -> Self {
        let mut p = Pdag::new(d.n_nodes());
        for (f, t) in d.edges() {
            p.orient(f, t);
        }
        p
    }

    pub fn n_nodes(&self) -> usize {
        self.n
    }

    #[inline]
    fn idx(&self, a: VarId, b: VarId) -> usize {
        a * self.n + b
    }

    pub fn has_directed(&self, from: VarId, to: VarId) -> bool {
        self.state[self.idx(from, to)] == DIR
    }

    pub fn has_undirected(&self, a: VarId, b: VarId) -> bool {
        self.state[self.idx(a, b)] == UND
    }

    /// Any edge (either mark) between `a` and `b`?
    pub fn adjacent(&self, a: VarId, b: VarId) -> bool {
        self.state[self.idx(a, b)] != NONE || self.state[self.idx(b, a)] != NONE
    }

    pub fn set_undirected(&mut self, a: VarId, b: VarId) {
        assert!(a != b);
        let (i, j) = (self.idx(a, b), self.idx(b, a));
        self.state[i] = UND;
        self.state[j] = UND;
    }

    /// Orient (or re-orient) the edge as `from -> to`.
    pub fn orient(&mut self, from: VarId, to: VarId) {
        assert!(from != to);
        let (i, j) = (self.idx(from, to), self.idx(to, from));
        self.state[i] = DIR;
        self.state[j] = NONE;
    }

    pub fn remove_edge(&mut self, a: VarId, b: VarId) {
        let (i, j) = (self.idx(a, b), self.idx(b, a));
        self.state[i] = NONE;
        self.state[j] = NONE;
    }

    /// All neighbors of `v` regardless of mark.
    pub fn adjacents(&self, v: VarId) -> Vec<VarId> {
        (0..self.n).filter(|&w| w != v && self.adjacent(v, w)).collect()
    }

    /// Nodes `w` with `w -> v`.
    pub fn directed_parents(&self, v: VarId) -> Vec<VarId> {
        (0..self.n).filter(|&w| self.has_directed(w, v)).collect()
    }

    /// Nodes `w` with `v -> w`.
    pub fn directed_children(&self, v: VarId) -> Vec<VarId> {
        (0..self.n).filter(|&w| self.has_directed(v, w)).collect()
    }

    /// Nodes `w` with `v — w`.
    pub fn undirected_neighbors(&self, v: VarId) -> Vec<VarId> {
        (0..self.n).filter(|&w| self.has_undirected(v, w)).collect()
    }

    pub fn n_edges(&self) -> usize {
        let mut c = 0;
        for a in 0..self.n {
            for b in 0..self.n {
                match self.state[self.idx(a, b)] {
                    DIR => c += 2,
                    UND if a < b => c += 2,
                    _ => {}
                }
            }
        }
        c / 2
    }

    /// Directed edges `(from, to)`, sorted.
    pub fn directed_edges(&self) -> Vec<(VarId, VarId)> {
        let mut es = Vec::new();
        for a in 0..self.n {
            for b in 0..self.n {
                if self.has_directed(a, b) {
                    es.push((a, b));
                }
            }
        }
        es
    }

    /// Undirected edges `(a, b)` with `a < b`, sorted.
    pub fn undirected_edges(&self) -> Vec<(VarId, VarId)> {
        let mut es = Vec::new();
        for a in 0..self.n {
            for b in (a + 1)..self.n {
                if self.has_undirected(a, b) {
                    es.push((a, b));
                }
            }
        }
        es
    }

    /// Underlying skeleton.
    pub fn skeleton(&self) -> UGraph {
        let mut g = UGraph::new(self.n);
        for a in 0..self.n {
            for b in (a + 1)..self.n {
                if self.adjacent(a, b) {
                    g.add_edge(a, b);
                }
            }
        }
        g
    }

    /// Is there a *directed* path `from ⇒ to` using only directed edges?
    pub fn has_directed_path(&self, from: VarId, to: VarId) -> bool {
        if from == to {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![from];
        seen[from] = true;
        while let Some(v) = stack.pop() {
            for w in 0..self.n {
                if self.has_directed(v, w) {
                    if w == to {
                        return true;
                    }
                    if !seen[w] {
                        seen[w] = true;
                        stack.push(w);
                    }
                }
            }
        }
        false
    }

    /// Extend to a DAG: orient remaining undirected edges consistently
    /// (greedy extension; exact for CPDAGs of DAGs in practice). Returns
    /// `None` if the directed part already has a cycle.
    pub fn to_dag(&self) -> Option<Dag> {
        let mut work = self.clone();
        // Repeatedly orient an undirected edge that does not create a new
        // v-structure or cycle (Dor & Tarsi-style extension, simplified).
        loop {
            let und = work.undirected_edges();
            if und.is_empty() {
                break;
            }
            let mut progressed = false;
            for (a, b) in und {
                // Prefer orientations that don't form a cycle.
                if !work.has_directed_path(b, a) {
                    work.orient(a, b);
                    progressed = true;
                } else if !work.has_directed_path(a, b) {
                    work.orient(b, a);
                    progressed = true;
                } else {
                    return None;
                }
            }
            if !progressed {
                return None;
            }
        }
        let mut dag = Dag::new(self.n);
        for (f, t) in work.directed_edges() {
            dag.add_edge_unchecked(f, t);
        }
        dag.topological_order().map(|_| dag)
    }

    /// The v-structures (colliders with non-adjacent parents) of the
    /// directed part, as `(min(a,b), max(a,b), c)`.
    pub fn v_structures(&self) -> Vec<(VarId, VarId, VarId)> {
        let mut vs = Vec::new();
        for c in 0..self.n {
            let ps = self.directed_parents(c);
            for i in 0..ps.len() {
                for j in (i + 1)..ps.len() {
                    let (a, b) = (ps[i], ps[j]);
                    if !self.adjacent(a, b) {
                        vs.push((a.min(b), a.max(b), c));
                    }
                }
            }
        }
        vs.sort_unstable();
        vs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_transition() {
        let mut p = Pdag::new(3);
        p.set_undirected(0, 1);
        assert!(p.has_undirected(1, 0));
        assert!(p.adjacent(0, 1));
        p.orient(0, 1);
        assert!(p.has_directed(0, 1));
        assert!(!p.has_undirected(0, 1));
        assert!(p.adjacent(1, 0));
        p.remove_edge(0, 1);
        assert!(!p.adjacent(0, 1));
    }

    #[test]
    fn neighbor_queries() {
        let mut p = Pdag::new(4);
        p.orient(0, 2);
        p.orient(1, 2);
        p.set_undirected(2, 3);
        assert_eq!(p.directed_parents(2), vec![0, 1]);
        assert_eq!(p.directed_children(0), vec![2]);
        assert_eq!(p.undirected_neighbors(2), vec![3]);
        assert_eq!(p.adjacents(2), vec![0, 1, 3]);
        assert_eq!(p.n_edges(), 3);
    }

    #[test]
    fn from_dag_roundtrip() {
        let mut d = Dag::new(3);
        d.add_edge(0, 1);
        d.add_edge(1, 2);
        let p = Pdag::from_dag(&d);
        assert_eq!(p.directed_edges(), vec![(0, 1), (1, 2)]);
        let d2 = p.to_dag().unwrap();
        assert_eq!(d2.edges(), d.edges());
    }

    #[test]
    fn to_dag_orients_undirected() {
        let mut p = Pdag::new(3);
        p.orient(0, 1);
        p.set_undirected(1, 2);
        let d = p.to_dag().unwrap();
        assert_eq!(d.n_edges(), 2);
        assert!(d.topological_order().is_some());
    }

    #[test]
    fn v_structures_detected() {
        let mut p = Pdag::new(3);
        p.orient(0, 2);
        p.orient(1, 2);
        assert_eq!(p.v_structures(), vec![(0, 1, 2)]);
    }

    #[test]
    fn directed_path() {
        let mut p = Pdag::new(4);
        p.orient(0, 1);
        p.orient(1, 2);
        p.set_undirected(2, 3);
        assert!(p.has_directed_path(0, 2));
        assert!(!p.has_directed_path(0, 3)); // undirected edge doesn't count
        assert!(!p.has_directed_path(2, 0));
    }
}
