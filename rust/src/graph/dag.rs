//! Directed acyclic graphs over `VarId`s.

use crate::core::VarId;

/// A DAG stored as parent and child adjacency lists (both kept sorted so
/// iteration order — and therefore every downstream computation — is
/// deterministic).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Dag {
    parents: Vec<Vec<VarId>>,
    children: Vec<Vec<VarId>>,
}

impl Dag {
    pub fn new(n: usize) -> Self {
        Dag { parents: vec![Vec::new(); n], children: vec![Vec::new(); n] }
    }

    pub fn n_nodes(&self) -> usize {
        self.parents.len()
    }

    pub fn n_edges(&self) -> usize {
        self.parents.iter().map(Vec::len).sum()
    }

    #[inline]
    pub fn parents(&self, v: VarId) -> &[VarId] {
        &self.parents[v]
    }

    #[inline]
    pub fn children(&self, v: VarId) -> &[VarId] {
        &self.children[v]
    }

    pub fn has_edge(&self, from: VarId, to: VarId) -> bool {
        self.parents[to].binary_search(&from).is_ok()
    }

    /// Add edge `from -> to`. Panics if it would create a cycle or a
    /// duplicate — structure-learning code checks before inserting.
    pub fn add_edge(&mut self, from: VarId, to: VarId) {
        assert!(from != to, "self loop");
        assert!(!self.has_edge(from, to), "duplicate edge {from}->{to}");
        assert!(
            !self.has_path(to, from),
            "edge {from}->{to} would create a cycle"
        );
        let i = self.parents[to].binary_search(&from).unwrap_err();
        self.parents[to].insert(i, from);
        let i = self.children[from].binary_search(&to).unwrap_err();
        self.children[from].insert(i, to);
    }

    /// Add edge without the (O(V+E)) cycle check; callers that build from a
    /// known-acyclic source (topologically generated synthetic networks,
    /// file parsers that validate afterwards) use this and then call
    /// [`Dag::topological_order`] once.
    pub fn add_edge_unchecked(&mut self, from: VarId, to: VarId) {
        assert!(from != to, "self loop");
        if let Err(i) = self.parents[to].binary_search(&from) {
            self.parents[to].insert(i, from);
            let j = self.children[from].binary_search(&to).unwrap_err();
            self.children[from].insert(j, to);
        }
    }

    pub fn remove_edge(&mut self, from: VarId, to: VarId) {
        if let Ok(i) = self.parents[to].binary_search(&from) {
            self.parents[to].remove(i);
            let j = self.children[from].binary_search(&to).unwrap();
            self.children[from].remove(j);
        }
    }

    /// DFS reachability `from -> to`.
    pub fn has_path(&self, from: VarId, to: VarId) -> bool {
        if from == to {
            return true;
        }
        let mut seen = vec![false; self.n_nodes()];
        let mut stack = vec![from];
        seen[from] = true;
        while let Some(v) = stack.pop() {
            for &c in &self.children[v] {
                if c == to {
                    return true;
                }
                if !seen[c] {
                    seen[c] = true;
                    stack.push(c);
                }
            }
        }
        false
    }

    /// Kahn topological order; `None` if a cycle slipped in via
    /// `add_edge_unchecked`.
    pub fn topological_order(&self) -> Option<Vec<VarId>> {
        let n = self.n_nodes();
        let mut indeg: Vec<usize> = (0..n).map(|v| self.parents[v].len()).collect();
        let mut queue: Vec<VarId> =
            (0..n).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let v = queue[head];
            head += 1;
            order.push(v);
            for &c in &self.children[v] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// All edges `(from, to)` in deterministic order.
    pub fn edges(&self) -> Vec<(VarId, VarId)> {
        let mut es = Vec::with_capacity(self.n_edges());
        for to in 0..self.n_nodes() {
            for &from in &self.parents[to] {
                es.push((from, to));
            }
        }
        es.sort_unstable();
        es
    }

    /// Markov blanket of `v`: parents ∪ children ∪ co-parents.
    pub fn markov_blanket(&self, v: VarId) -> Vec<VarId> {
        let mut mb: Vec<VarId> = self.parents[v].to_vec();
        for &c in &self.children[v] {
            mb.push(c);
            for &p in &self.parents[c] {
                if p != v {
                    mb.push(p);
                }
            }
        }
        mb.sort_unstable();
        mb.dedup();
        mb
    }

    /// Undirected skeleton.
    pub fn skeleton(&self) -> super::UGraph {
        let mut g = super::UGraph::new(self.n_nodes());
        for (a, b) in self.edges() {
            g.add_edge(a, b);
        }
        g
    }

    /// The CPDAG-defining v-structures `a -> c <- b` with `a`,`b`
    /// non-adjacent, as `(min(a,b), max(a,b), c)` triples.
    pub fn v_structures(&self) -> Vec<(VarId, VarId, VarId)> {
        let mut vs = Vec::new();
        for c in 0..self.n_nodes() {
            let ps = &self.parents[c];
            for i in 0..ps.len() {
                for j in (i + 1)..ps.len() {
                    let (a, b) = (ps[i], ps[j]);
                    if !self.has_edge(a, b) && !self.has_edge(b, a) {
                        vs.push((a, b, c));
                    }
                }
            }
        }
        vs.sort_unstable();
        vs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Dag {
        // 0 -> 1 -> 2
        let mut d = Dag::new(3);
        d.add_edge(0, 1);
        d.add_edge(1, 2);
        d
    }

    #[test]
    fn add_remove_edges() {
        let mut d = chain();
        assert!(d.has_edge(0, 1));
        assert!(!d.has_edge(1, 0));
        assert_eq!(d.n_edges(), 2);
        d.remove_edge(0, 1);
        assert!(!d.has_edge(0, 1));
        assert_eq!(d.n_edges(), 1);
    }

    #[test]
    #[should_panic]
    fn cycle_rejected() {
        let mut d = chain();
        d.add_edge(2, 0);
    }

    #[test]
    fn topo_order_valid() {
        let mut d = Dag::new(4);
        d.add_edge(3, 1);
        d.add_edge(1, 0);
        d.add_edge(3, 2);
        let order = d.topological_order().unwrap();
        let pos: Vec<usize> =
            (0..4).map(|v| order.iter().position(|&o| o == v).unwrap()).collect();
        for (f, t) in d.edges() {
            assert!(pos[f] < pos[t]);
        }
    }

    #[test]
    fn unchecked_cycle_detected_by_topo() {
        let mut d = Dag::new(2);
        d.add_edge_unchecked(0, 1);
        d.add_edge_unchecked(1, 0);
        assert!(d.topological_order().is_none());
    }

    #[test]
    fn markov_blanket_collider() {
        // 0 -> 2 <- 1, 2 -> 3
        let mut d = Dag::new(4);
        d.add_edge(0, 2);
        d.add_edge(1, 2);
        d.add_edge(2, 3);
        assert_eq!(d.markov_blanket(0), vec![1, 2]);
        assert_eq!(d.markov_blanket(2), vec![0, 1, 3]);
    }

    #[test]
    fn v_structures_found() {
        let mut d = Dag::new(3);
        d.add_edge(0, 2);
        d.add_edge(1, 2);
        assert_eq!(d.v_structures(), vec![(0, 1, 2)]);
        // Marrying the parents removes the v-structure.
        let mut d2 = d.clone();
        d2.add_edge(0, 1);
        assert!(d2.v_structures().is_empty());
    }

    #[test]
    fn skeleton_drops_direction() {
        let d = chain();
        let s = d.skeleton();
        assert!(s.has_edge(1, 0));
        assert!(s.has_edge(2, 1));
        assert_eq!(s.n_edges(), 2);
    }
}
