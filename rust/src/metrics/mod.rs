//! Evaluation metrics (paper §2, auxiliary features): structural Hamming
//! distance for learning, Hellinger distance for inference, plus KL
//! divergence, total variation and classification accuracy.

use crate::core::VarId;
use crate::graph::{Dag, Pdag};

/// Structural Hamming distance between two PDAGs/CPDAGs (Acid & de Campos
/// 2003; Tsamardinos et al. 2006 convention): number of edge insertions,
/// deletions and re-orientations needed to turn `learned` into `truth`.
///
/// * missing or extra adjacency → 1
/// * shared adjacency with different mark (direction flip, or directed vs
///   undirected) → 1
pub fn shd(learned: &Pdag, truth: &Pdag) -> usize {
    assert_eq!(learned.n_nodes(), truth.n_nodes());
    let n = learned.n_nodes();
    let mut dist = 0;
    for a in 0..n {
        for b in (a + 1)..n {
            let la = learned.adjacent(a, b);
            let ta = truth.adjacent(a, b);
            match (la, ta) {
                (false, false) => {}
                (true, false) | (false, true) => dist += 1,
                (true, true) => {
                    let same = (learned.has_undirected(a, b) && truth.has_undirected(a, b))
                        || (learned.has_directed(a, b) && truth.has_directed(a, b))
                        || (learned.has_directed(b, a) && truth.has_directed(b, a));
                    if !same {
                        dist += 1;
                    }
                }
            }
        }
    }
    dist
}

/// SHD against the *CPDAG* of a ground-truth DAG — the fair comparison for
/// constraint-based learners, which can only identify structure up to its
/// Markov equivalence class.
pub fn shd_vs_dag_cpdag(learned: &Pdag, truth_dag: &Dag) -> usize {
    shd(learned, &cpdag_of(truth_dag))
}

/// The CPDAG (Markov-equivalence-class representative) of a DAG: keep
/// v-structure edges directed, then close under Meek's rules; everything
/// else is undirected.
pub fn cpdag_of(dag: &Dag) -> Pdag {
    let mut p = Pdag::from_skeleton(&dag.skeleton());
    for (a, b, c) in dag.v_structures() {
        p.orient(a, c);
        p.orient(b, c);
    }
    crate::structure::orientation::apply_meek_rules(&mut p);
    p
}

/// Hellinger distance between two discrete distributions:
/// `H(p,q) = sqrt(1/2 * sum_i (sqrt(p_i) - sqrt(q_i))^2)`, in `[0, 1]`.
pub fn hellinger(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let s: f64 = p
        .iter()
        .zip(q)
        .map(|(&a, &b)| {
            let d = a.max(0.0).sqrt() - b.max(0.0).sqrt();
            d * d
        })
        .sum();
    (s / 2.0).sqrt()
}

/// Mean Hellinger distance across per-variable posteriors — the aggregate
/// inference-accuracy number benches E7 report.
pub fn mean_hellinger(ps: &[Vec<f64>], qs: &[Vec<f64>]) -> f64 {
    assert_eq!(ps.len(), qs.len());
    if ps.is_empty() {
        return 0.0;
    }
    ps.iter().zip(qs).map(|(p, q)| hellinger(p, q)).sum::<f64>() / ps.len() as f64
}

/// KL divergence `KL(p || q)` with absolute-continuity guard
/// (`0 log 0/q = 0`; `p>0, q=0` contributes `inf` clamped to a large
/// finite value so aggregates stay usable).
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    p.iter()
        .zip(q)
        .map(|(&a, &b)| {
            if a <= 0.0 {
                0.0
            } else if b <= 0.0 {
                1e9
            } else {
                a * (a / b).ln()
            }
        })
        .sum()
}

/// Total variation distance `1/2 * sum |p_i - q_i|`.
pub fn total_variation(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    p.iter().zip(q).map(|(&a, &b)| (a - b).abs()).sum::<f64>() / 2.0
}

/// Classification accuracy from (predicted, actual) state pairs.
pub fn accuracy(pairs: &[(usize, usize)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    pairs.iter().filter(|(p, a)| p == a).count() as f64 / pairs.len() as f64
}

/// Confusion matrix `m[actual][predicted]` for a `card`-state variable.
pub fn confusion_matrix(pairs: &[(usize, usize)], card: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; card]; card];
    for &(pred, actual) in pairs {
        m[actual][pred] += 1;
    }
    m
}

/// Skeleton precision/recall/F1 of a learned PDAG against a true DAG's
/// skeleton — the secondary learning-quality numbers in bench E8.
pub fn skeleton_prf(learned: &Pdag, truth: &Dag) -> (f64, f64, f64) {
    let n = truth.n_nodes();
    let t = truth.skeleton();
    let (mut tp, mut fp, mut fn_) = (0usize, 0usize, 0usize);
    for a in 0..n {
        for b in (a + 1)..n {
            match (learned.adjacent(a, b), t.has_edge(a, b)) {
                (true, true) => tp += 1,
                (true, false) => fp += 1,
                (false, true) => fn_ += 1,
                _ => {}
            }
        }
    }
    let prec = if tp + fp == 0 { 1.0 } else { tp as f64 / (tp + fp) as f64 };
    let rec = if tp + fn_ == 0 { 1.0 } else { tp as f64 / (tp + fn_) as f64 };
    let f1 = if prec + rec == 0.0 { 0.0 } else { 2.0 * prec * rec / (prec + rec) };
    (prec, rec, f1)
}

/// Edge-difference report between two DAGs (extra, missing, reversed) —
/// used by the format-transform CLI for human-readable diffs.
pub fn dag_diff(
    a: &Dag,
    b: &Dag,
) -> (Vec<(VarId, VarId)>, Vec<(VarId, VarId)>, Vec<(VarId, VarId)>) {
    let mut extra = Vec::new();
    let mut missing = Vec::new();
    let mut reversed = Vec::new();
    for (f, t) in a.edges() {
        if b.has_edge(f, t) {
        } else if b.has_edge(t, f) {
            if f < t {
                reversed.push((f, t));
            }
        } else {
            extra.push((f, t));
        }
    }
    for (f, t) in b.edges() {
        if !a.has_edge(f, t) && !a.has_edge(t, f) {
            missing.push((f, t));
        }
    }
    (extra, missing, reversed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hellinger_bounds() {
        assert_eq!(hellinger(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        assert!((hellinger(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        let h = hellinger(&[0.5, 0.5], &[0.9, 0.1]);
        assert!(h > 0.0 && h < 1.0);
    }

    #[test]
    fn kl_zero_iff_equal() {
        assert!(kl_divergence(&[0.3, 0.7], &[0.3, 0.7]).abs() < 1e-12);
        assert!(kl_divergence(&[0.3, 0.7], &[0.7, 0.3]) > 0.0);
    }

    #[test]
    fn tv_symmetric() {
        let (p, q) = ([0.2, 0.8], [0.6, 0.4]);
        assert!((total_variation(&p, &q) - total_variation(&q, &p)).abs() < 1e-12);
        assert!((total_variation(&p, &q) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn shd_identical_zero() {
        let mut d = Dag::new(3);
        d.add_edge(0, 1);
        d.add_edge(1, 2);
        let p = Pdag::from_dag(&d);
        assert_eq!(shd(&p, &p.clone()), 0);
    }

    #[test]
    fn shd_counts_each_difference() {
        let mut t = Dag::new(4);
        t.add_edge(0, 1);
        t.add_edge(2, 3);
        let truth = Pdag::from_dag(&t);
        // learned: 0->1 reversed, 2-3 missing, extra 1-2 undirected
        let mut l = Pdag::new(4);
        l.orient(1, 0);
        l.set_undirected(1, 2);
        assert_eq!(shd(&l, &truth), 3);
    }

    #[test]
    fn accuracy_and_confusion() {
        let pairs = [(0, 0), (1, 1), (0, 1), (1, 1)];
        assert!((accuracy(&pairs) - 0.75).abs() < 1e-12);
        let m = confusion_matrix(&pairs, 2);
        assert_eq!(m[1][0], 1); // one actual-1 predicted-0
        assert_eq!(m[1][1], 2);
    }

    #[test]
    fn skeleton_prf_perfect() {
        let mut d = Dag::new(3);
        d.add_edge(0, 2);
        let p = Pdag::from_dag(&d);
        let (prec, rec, f1) = skeleton_prf(&p, &d);
        assert_eq!((prec, rec, f1), (1.0, 1.0, 1.0));
    }
}
