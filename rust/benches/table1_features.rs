//! T1 — Table 1 reproduction: the feature matrix.
//!
//! The paper's only table is a feature comparison; its Fast-PGM row
//! claims structure learning, parameter learning, exact inference,
//! approximate inference, open-source, parallelization. This harness
//! *executes* every claimed feature end-to-end on ASIA and prints the
//! verified row (a claim is ✓ only if the corresponding code path ran and
//! produced a sane result).

use fastpgm::core::Evidence;
use fastpgm::inference::approx::{ApproxOptions, LikelihoodWeighting, LoopyBp, LoopyBpOptions};
use fastpgm::inference::exact::{JunctionTree, VariableElimination};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::repository;
use fastpgm::parameter::{mle, MleOptions};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{pc_stable, pc_stable_parallel, PcOptions};
use std::time::Instant;

fn check(name: &str, f: impl FnOnce() -> bool) -> bool {
    let t0 = Instant::now();
    let ok = f();
    println!(
        "  {:<22} {}  ({:.1?})",
        name,
        if ok { "\u{2713}" } else { "\u{2717}" },
        t0.elapsed()
    );
    ok
}

fn main() {
    println!("== T1: Table 1 feature matrix — executed, not asserted ==");
    let net = repository::asia();
    let mut rng = Pcg::seed_from(1);
    let data = forward_sample_dataset(&net, 10_000, &mut rng);
    let ev = Evidence::new().with(net.var_index("xray").unwrap(), 1);

    let mut all = true;
    all &= check("structure learning", || {
        pc_stable(&data, &PcOptions::default()).n_edges() > 0
    });
    all &= check("parameter learning", || {
        mle(&data, net.dag(), &MleOptions::default()).n_parameters() == net.n_parameters()
    });
    all &= check("exact inf. (JT)", || {
        let p = JunctionTree::build(&net).engine().query(3, &ev);
        (p.iter().sum::<f64>() - 1.0).abs() < 1e-9
    });
    all &= check("exact inf. (VE)", || {
        let p = VariableElimination::new(&net).query(3, &ev);
        (p.iter().sum::<f64>() - 1.0).abs() < 1e-9
    });
    all &= check("approx inf. (LBP)", || {
        let p = LoopyBp::new(&net, LoopyBpOptions::default()).query(3, &ev);
        (p.iter().sum::<f64>() - 1.0).abs() < 1e-9
    });
    all &= check("approx inf. (sampling)", || {
        let opts = ApproxOptions { n_samples: 20_000, ..Default::default() };
        let p = LikelihoodWeighting::new(&net, opts).query(3, &ev);
        (p.iter().sum::<f64>() - 1.0).abs() < 1e-9
    });
    all &= check("parallelization", || {
        let seq = pc_stable(&data, &PcOptions::default());
        let par = pc_stable_parallel(&data, &PcOptions { threads: 4, ..Default::default() });
        seq.graph == par.graph
    });
    all &= check("open-source formats", || {
        let bif = fastpgm::io::bif::to_string(&net);
        fastpgm::io::bif::from_str(&bif).is_ok()
    });

    println!("\nTable 1, Fast-PGM row (this reproduction):");
    println!(
        "| Library  | Structure learn. | Param. learn. | Ex. inf. | Appr. inf. | Open-source | Parallel. | Language |"
    );
    println!(
        "| Fast-PGM | {s} | {s} | {s} | {s} | {s} | {s} | Rust+JAX/Pallas |",
        s = if all { "\u{2713}" } else { "\u{2717}" }
    );
    assert!(all, "a claimed feature failed to execute");
}
