//! E10 — constraint-based vs score-based structure learning: PC-stable
//! against the greedy BIC hill-climbing baseline (the comparison class of
//! the Table-1 libraries: pcalg/ParallelPC are constraint-based, bnlearn
//! ships both). Reports runtime, SHD and skeleton F1 side by side; also
//! pits the MCMC baseline (Gibbs) against the paper's importance samplers.

use fastpgm::benchkit::{bench, fmt_duration, report};
use fastpgm::core::Evidence;
use fastpgm::inference::approx::{AisBn, ApproxOptions, GibbsSampling, LikelihoodWeighting};
use fastpgm::inference::exact::JunctionTree;
use fastpgm::inference::InferenceEngine;
use fastpgm::metrics::{cpdag_of, mean_hellinger, shd_vs_dag_cpdag, skeleton_prf};
use fastpgm::network::{repository, synthetic::SyntheticSpec};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{hill_climb, pc_stable, HcOptions, PcOptions};

fn main() {
    println!("== E10: PC-stable vs hill-climbing (BIC) ==");
    for net in [repository::survey(), SyntheticSpec::child_like().generate(1)] {
        let mut rng = Pcg::seed_from(10_010);
        let data = forward_sample_dataset(&net, 20_000, &mut rng);

        let t0 = std::time::Instant::now();
        let pc = pc_stable(&data, &PcOptions { alpha: 0.05, ..Default::default() });
        let pc_time = t0.elapsed();
        let pc_shd = shd_vs_dag_cpdag(&pc.graph, net.dag());
        let (_, _, pc_f1) = skeleton_prf(&pc.graph, net.dag());

        let t0 = std::time::Instant::now();
        let hc = hill_climb(&data, &HcOptions::default());
        let hc_time = t0.elapsed();
        let hc_cpdag = cpdag_of(&hc.dag);
        let hc_shd = shd_vs_dag_cpdag(&hc_cpdag, net.dag());
        let (_, _, hc_f1) = skeleton_prf(&hc_cpdag, net.dag());

        println!(
            "\n-- {} ({} vars, 20k rows) --",
            net.name(),
            net.n_vars()
        );
        println!("{:<16} {:>10} {:>6} {:>8}", "algorithm", "time", "SHD", "skel F1");
        println!(
            "{:<16} {:>10} {:>6} {:>8.3}",
            "pc-stable",
            fmt_duration(pc_time),
            pc_shd,
            pc_f1
        );
        println!(
            "{:<16} {:>10} {:>6} {:>8.3}",
            "hill-climb BIC",
            fmt_duration(hc_time),
            hc_shd,
            hc_f1
        );
    }

    println!("\n== E10b: Gibbs (MCMC baseline) vs importance samplers ==");
    let net = repository::cancer();
    let ev = Evidence::new().with(3, 1);
    let jt = JunctionTree::build(&net);
    let truth = jt.engine().query_all(&ev);
    let opts = ApproxOptions { n_samples: 30_000, ..Default::default() };
    let results = vec![
        bench("gibbs 30k sweeps", 0, 3, || {
            GibbsSampling::new(&net, opts.clone()).query_all(&ev)
        }),
        bench("likelihood-weighting 30k", 0, 3, || {
            LikelihoodWeighting::new(&net, opts.clone()).query_all(&ev)
        }),
        bench("ais-bn 30k", 0, 3, || {
            AisBn::new(&net, opts.clone()).query_all(&ev)
        }),
    ];
    report("cancer, xray=pos (30k samples each)", &results);
    let h_gibbs =
        mean_hellinger(&GibbsSampling::new(&net, opts.clone()).query_all(&ev), &truth);
    let h_lw =
        mean_hellinger(&LikelihoodWeighting::new(&net, opts.clone()).query_all(&ev), &truth);
    let h_ais = mean_hellinger(&AisBn::new(&net, opts).query_all(&ev), &truth);
    println!("mean Hellinger: gibbs {h_gibbs:.5}  lw {h_lw:.5}  ais {h_ais:.5}");
}
