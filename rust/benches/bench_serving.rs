//! Serving-path bench — posterior-query throughput through the three
//! serving strategies, writing the perf trajectory to `BENCH_serving.json`:
//!
//! * `rebuild`   — what the seed's serving layer did for general queries:
//!   build the junction tree from scratch (moralize + triangulate +
//!   assign) for *every* request, then calibrate and read the marginal.
//! * `compiled`  — the compile-vs-query split: one [`CompiledTree`] per
//!   network, one calibration per request (no cache).
//! * `cached`    — the full [`QueryEngine`]: compiled tree + LRU
//!   calibration cache keyed on the evidence signature.
//!
//! Traffic model: a bounded pool of distinct evidence sets cycled across
//! requests (serving traffic repeats itself), rotating query targets.
//! The cached mode's results are cross-checked against per-query rebuilds
//! at 1e-12 — the cache must be bit-compatible with cold inference.

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, report, Measurement};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::{
    CompiledTree, JunctionTree, QueryEngine, QueryEngineConfig,
};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::{repository, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::testkit;
use std::path::Path;
use std::time::{Duration, Instant};

const QUERIES: usize = 256;
const EVIDENCE_POOL: usize = 16;
const CACHE_CAPACITY: usize = 64;

/// The request stream: (evidence, query var) pairs with pool reuse,
/// drawn from the shared serving-traffic model in `testkit`.
fn workload(net: &BayesianNetwork, seed: u64) -> Vec<(Evidence, usize)> {
    let mut rng = Pcg::seed_from(seed);
    let pool = testkit::gen_evidence_pool(&mut rng, net, EVIDENCE_POOL, 2);
    (0..QUERIES)
        .map(|i| {
            let ev = pool[i % pool.len()].clone();
            let var = testkit::gen_query_var(&mut rng, net, &ev);
            (ev, var)
        })
        .collect()
}

/// Run one strategy over the stream, returning per-query posteriors and
/// latencies.
fn drive(
    stream: &[(Evidence, usize)],
    mut answer: impl FnMut(&Evidence, usize) -> Vec<f64>,
) -> (Vec<Vec<f64>>, Vec<Duration>) {
    let mut posts = Vec::with_capacity(stream.len());
    let mut latencies = Vec::with_capacity(stream.len());
    for (ev, var) in stream {
        let t0 = Instant::now();
        let p = answer(ev, *var);
        latencies.push(t0.elapsed());
        posts.push(p);
    }
    (posts, latencies)
}

fn scenario_json(
    net: &str,
    mode: &str,
    latencies: &[Duration],
    extra: Vec<(&str, Json)>,
) -> Json {
    let total: f64 = latencies.iter().map(Duration::as_secs_f64).sum();
    let m = Measurement { label: mode.to_string(), samples: latencies.to_vec() };
    let mut pairs = vec![
        ("net", Json::str(net)),
        ("mode", Json::str(mode)),
        ("queries", Json::num(latencies.len() as f64)),
        ("throughput_qps", Json::num(latencies.len() as f64 / total.max(1e-12))),
        ("p50_us", Json::num(m.percentile(50.0).as_secs_f64() * 1e6)),
        ("p99_us", Json::num(m.percentile(99.0).as_secs_f64() * 1e6)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

fn main() {
    println!("== serving: posterior-query throughput (rebuild vs compiled vs cached) ==");
    let mut scenarios: Vec<Json> = Vec::new();
    for name in ["asia", "child_like", "alarm_like"] {
        let net = repository::by_name_extended(name).expect("known network");
        let stream = workload(&net, 0xBEEF ^ name.len() as u64);

        // 1. Per-query tree rebuild (the pre-split serving cost).
        let (rebuild_posts, rebuild_lat) = drive(&stream, |ev, var| {
            let jt = JunctionTree::build(&net);
            let mut engine = jt.engine();
            engine.query(var, ev)
        });

        // 2. Compiled once, calibrated per query (no cache).
        let compiled = CompiledTree::compile(&net);
        let (compiled_posts, compiled_lat) =
            drive(&stream, |ev, var| compiled.calibrate(ev).posterior(var));

        // 3. Compiled + LRU calibration cache (the QueryEngine).
        let engine = QueryEngine::with_config(
            &net,
            QueryEngineConfig::new().with_cache_capacity(CACHE_CAPACITY),
        );
        let (cached_posts, cached_lat) =
            drive(&stream, |ev, var| engine.posterior(var, ev));
        let cache_stats = engine.stats();

        // Bit-compatibility: cached and compiled paths must reproduce the
        // cold rebuild to within 1e-12.
        let mut dev_cached: f64 = 0.0;
        let mut dev_compiled: f64 = 0.0;
        for ((a, b), c) in rebuild_posts.iter().zip(&cached_posts).zip(&compiled_posts) {
            for ((x, y), z) in a.iter().zip(b).zip(c) {
                dev_cached = dev_cached.max((x - y).abs());
                dev_compiled = dev_compiled.max((x - z).abs());
            }
        }
        assert!(
            dev_cached <= 1e-12 && dev_compiled <= 1e-12,
            "{name}: serving deviates from cold inference \
             (cached {dev_cached:.2e}, compiled {dev_compiled:.2e})"
        );

        let total = |lat: &[Duration]| -> f64 {
            lat.iter().map(Duration::as_secs_f64).sum()
        };
        let speedup_compiled = total(&rebuild_lat) / total(&compiled_lat).max(1e-12);
        let speedup_cached = total(&rebuild_lat) / total(&cached_lat).max(1e-12);

        let rows = [
            ("rebuild/query", rebuild_lat.clone()),
            ("compiled tree", compiled_lat.clone()),
            ("cached (QueryEngine)", cached_lat.clone()),
        ]
        .map(|(label, samples)| Measurement { label: format!("{name} {label}"), samples });
        report(
            &format!(
                "{name} ({} vars, {QUERIES} queries, pool={EVIDENCE_POOL})",
                net.n_vars()
            ),
            &rows,
        );
        println!(
            "  speedup vs rebuild: compiled {speedup_compiled:.1}x, cached {speedup_cached:.1}x \
             (cache hit rate {:.3}); max dev cached {dev_cached:.1e}",
            cache_stats.hit_rate()
        );
        if speedup_cached < 2.0 {
            println!("  WARNING: cached speedup below the 2x serving target");
        }

        scenarios.push(scenario_json(name, "rebuild", &rebuild_lat, vec![]));
        scenarios.push(scenario_json(
            name,
            "compiled",
            &compiled_lat,
            vec![("speedup_vs_rebuild", Json::num(speedup_compiled))],
        ));
        scenarios.push(scenario_json(
            name,
            "cached",
            &cached_lat,
            vec![
                ("speedup_vs_rebuild", Json::num(speedup_cached)),
                ("cache_hit_rate", Json::num(cache_stats.hit_rate())),
                ("cache_hits", Json::num(cache_stats.hits as f64)),
                ("cache_misses", Json::num(cache_stats.misses() as f64)),
                ("cache_warm_starts", Json::num(cache_stats.warm_starts as f64)),
                ("max_abs_dev_vs_rebuild", Json::num(dev_cached)),
            ],
        ));
    }

    let out = Json::obj([
        ("bench", Json::str("serving")),
        (
            "config",
            Json::obj([
                ("queries", Json::num(QUERIES as f64)),
                ("evidence_pool", Json::num(EVIDENCE_POOL as f64)),
                ("cache_capacity", Json::num(CACHE_CAPACITY as f64)),
            ]),
        ),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = Path::new("BENCH_serving.json");
    benchkit::json::write(path, &out).expect("writing BENCH_serving.json");
    println!("\nwrote {}", path.display());
}
