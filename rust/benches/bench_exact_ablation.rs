//! E4 — exact-inference ablations:
//! (a) potential-table reorganization (opt v): odometer index maintenance
//!     on canonical tables vs per-entry divide/modulo decoding;
//! (b) root selection (opt iv): critical-path-minimizing root vs default.

use fastpgm::benchkit::{bench, report};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::{triangulation::EliminationHeuristic, CalibrationMode, JunctionTree};
use fastpgm::network::synthetic::SyntheticSpec;
use fastpgm::potential::ops::IndexMode;
use fastpgm::potential::PotentialTable;
use fastpgm::rng::Pcg;

fn random_table(vars: Vec<usize>, cards: Vec<usize>, seed: u64) -> PotentialTable {
    let mut rng = Pcg::seed_from(seed);
    let mut t = PotentialTable::zeros(vars, cards);
    for x in t.data_mut() {
        *x = rng.next_f64() + 0.01;
    }
    t
}

fn main() {
    println!("== E4: potential-table + root-selection ablations ==");

    // (a) table-op microbenchmarks on realistic clique sizes.
    let big = random_table(vec![0, 1, 2, 3, 4, 5], vec![4, 4, 4, 4, 4, 4], 1); // 4096 entries
    let sep = random_table(vec![1, 3], vec![4, 4], 2);
    let ops = vec![
        bench("product naive-decode", 3, 20, || {
            big.product(&sep, IndexMode::NaiveDecode)
        }),
        bench("product odometer (opt v)", 3, 20, || {
            big.product(&sep, IndexMode::Odometer)
        }),
        bench("marginalize naive-decode", 3, 20, || {
            big.marginalize_keep(&[1, 3], IndexMode::NaiveDecode)
        }),
        bench("marginalize odometer (opt v)", 3, 20, || {
            big.marginalize_keep(&[1, 3], IndexMode::Odometer)
        }),
        bench("multiply_subset naive-decode", 3, 20, || {
            let mut c = big.clone();
            c.multiply_subset(&sep, IndexMode::NaiveDecode);
            c
        }),
        bench("multiply_subset odometer (opt v)", 3, 20, || {
            let mut c = big.clone();
            c.multiply_subset(&sep, IndexMode::Odometer);
            c
        }),
    ];
    report("potential-table operations (4096-entry clique)", &ops);

    // (a') whole-calibration with each index mode.
    let net = SyntheticSpec::hepar2_like().generate(1);
    let jt = JunctionTree::build(&net);
    let ev = Evidence::new().with(5, 1).with(30, 0);
    let modes = [("naive-decode", IndexMode::NaiveDecode), ("odometer", IndexMode::Odometer)];
    for (label, mode) in modes {
        let mut eng = jt.engine();
        // Pin the classic three-op path: this ablation isolates the index
        // strategy of the generic table ops, and the fused kernels (the
        // default, measured separately in bench_kernels) only exist for
        // the odometer strategy.
        eng.kernel = fastpgm::inference::exact::KernelMode::Classic;
        eng.index_mode = mode;
        let ev = ev.clone();
        let r = bench(format!("hepar2_like calibration, {label}"), 1, 5, move || {
            eng.calibrate(&Evidence::new());
            eng.calibrate(&ev.clone());
            eng.evidence_probability()
        });
        report(&format!("JT calibration index mode: {label}"), &[r]);
    }

    // (b) root selection.
    let net = SyntheticSpec::alarm_like().generate(1);
    for (label, select) in [("default root", false), ("selected root (opt iv)", true)] {
        let jt = JunctionTree::build_with(&net, EliminationHeuristic::MinFill, select);
        println!(
            "\nalarm_like, {label}: {} levels, widest level {}",
            jt.levels.len(),
            jt.levels.iter().map(Vec::len).max().unwrap_or(0)
        );
        let ev = Evidence::new().with(7, 1);
        let mut eng = jt.parallel_engine(CalibrationMode::InterClique, 4);
        let r = bench(format!("alarm_like inter-clique x4, {label}"), 1, 5, move || {
            eng.calibrate(&Evidence::new());
            eng.calibrate(&ev.clone());
            eng.evidence_probability()
        });
        report(label, &[r]);
    }
}
