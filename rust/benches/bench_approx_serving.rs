//! Approximate-serving bench, writing `BENCH_approx_serving.json`:
//!
//! * **scaling** — chunked likelihood-weighting samples/sec as the shared
//!   [`WorkPool`] grows (1, 2, 4, ... workers), on a mid-size synthetic
//!   network. The chunk RNG streams make every row bit-identical, so the
//!   sweep measures pure scheduling, not estimator drift.
//! * **tradeoff** — exact (compiled junction tree) vs each wrapped
//!   sampler: latency of one all-marginals answer and its mean L1 error
//!   against the exact posteriors, at a fixed sample budget.

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, fmt_duration};
use fastpgm::core::Evidence;
use fastpgm::inference::approx::ApproxOptions;
use fastpgm::inference::engine::{ApproxEngine, SamplerKind};
use fastpgm::inference::exact::QueryEngine;
use fastpgm::network::repository;
use fastpgm::parallel::WorkPool;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

const SCALING_SAMPLES: usize = 200_000;
const TRADEOFF_SAMPLES: usize = 40_000;

fn mean_l1(posts: &[Vec<f64>], reference: &[Vec<f64>]) -> f64 {
    let total: f64 = posts
        .iter()
        .zip(reference)
        .map(|(p, q)| p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>())
        .sum();
    total / posts.len() as f64
}

fn main() {
    let mut scaling = Vec::new();

    // -- Part 1: samples/sec vs worker count ------------------------------
    let net = repository::by_name_extended("child_like").expect("known preset");
    let ev = Evidence::new().with(0, 1);
    let max_threads = fastpgm::parallel::default_threads().max(1);
    let mut counts: Vec<usize> = [1usize, 2, 4]
        .into_iter()
        .filter(|&c| c <= max_threads)
        .collect();
    if max_threads > 4 {
        counts.push(max_threads);
    }
    if counts.len() < 2 {
        counts = vec![1, 2];
    }
    println!(
        "== approx serving: chunked likelihood weighting on {} ({} vars, {} samples) ==",
        net.name(),
        net.n_vars(),
        SCALING_SAMPLES
    );
    let mut base_sps = 0.0f64;
    for &workers in &counts {
        let engine = ApproxEngine::new(
            &net,
            SamplerKind::LikelihoodWeighting,
            ApproxOptions { n_samples: SCALING_SAMPLES, ..Default::default() },
        )
        .with_pool(Arc::new(WorkPool::new(workers)));
        std::hint::black_box(engine.run(&ev)); // warmup
        let t0 = Instant::now();
        let run = engine.run(&ev);
        let secs = t0.elapsed().as_secs_f64().max(1e-12);
        let sps = run.samples_drawn as f64 / secs;
        if base_sps == 0.0 {
            base_sps = sps;
        }
        println!(
            "  workers={workers:<2} {:>12.0} samples/s  speedup {:.2}x",
            sps,
            sps / base_sps
        );
        scaling.push(Json::obj([
            ("workers", Json::num(workers as f64)),
            ("samples", Json::num(run.samples_drawn as f64)),
            ("samples_per_sec", Json::num(sps)),
            ("speedup_vs_1", Json::num(sps / base_sps)),
        ]));
    }

    // -- Part 2: exact vs approx latency/accuracy -------------------------
    let net = repository::asia();
    let exact = QueryEngine::new(&net);
    let ev = Evidence::new()
        .with(net.var_index("xray").unwrap(), 1)
        .with(net.var_index("smoke").unwrap(), 1);
    let reference = exact.posterior_all(&ev);
    let mut tradeoff = Vec::new();

    println!("\n== exact vs approx: all-marginals on asia ==");
    // Exact row: cold calibration per answer (clearing the cache keeps the
    // comparison honest — a cache hit would be near-free).
    let m = benchkit::bench("exact cold calibration", 5, 200, || {
        exact.clear_cache();
        exact.posterior_all(&ev)
    });
    let exact_latency = m.mean();
    println!("  {:<22} latency {:>10}  mean L1 0", "exact", fmt_duration(exact_latency));
    tradeoff.push(Json::obj([
        ("engine", Json::str("exact")),
        ("latency_us", Json::num(exact_latency.as_secs_f64() * 1e6)),
        ("mean_l1_error", Json::num(0.0)),
        ("samples", Json::num(0.0)),
    ]));

    let pool = Arc::new(WorkPool::new(max_threads));
    let kinds = [
        SamplerKind::LikelihoodWeighting,
        SamplerKind::AisBn,
        SamplerKind::EpisBn,
        SamplerKind::Gibbs,
    ];
    for kind in kinds {
        let engine = ApproxEngine::new(
            &net,
            kind,
            ApproxOptions { n_samples: TRADEOFF_SAMPLES, ..Default::default() },
        )
        .with_pool(Arc::clone(&pool));
        std::hint::black_box(engine.run(&ev)); // warmup
        let t0 = Instant::now();
        let run = engine.run(&ev);
        let latency = t0.elapsed();
        let l1 = mean_l1(&run.posteriors, &reference);
        println!(
            "  {:<22} latency {:>10}  mean L1 {l1:.4}",
            kind.name(),
            fmt_duration(latency)
        );
        tradeoff.push(Json::obj([
            ("engine", Json::str(kind.name())),
            ("latency_us", Json::num(latency.as_secs_f64() * 1e6)),
            ("mean_l1_error", Json::num(l1)),
            ("samples", Json::num(run.samples_drawn as f64)),
        ]));
    }

    let out = Json::obj([
        ("bench", Json::str("approx_serving")),
        (
            "config",
            Json::obj([
                ("scaling_samples", Json::num(SCALING_SAMPLES as f64)),
                ("tradeoff_samples", Json::num(TRADEOFF_SAMPLES as f64)),
                ("max_threads", Json::num(max_threads as f64)),
            ]),
        ),
        ("scaling", Json::Arr(scaling)),
        ("tradeoff", Json::Arr(tradeoff)),
    ]);
    let path = Path::new("BENCH_approx_serving.json");
    benchkit::json::write(path, &out).expect("writing BENCH_approx_serving.json");
    println!("\nwrote {}", path.display());
}
