//! E7 — inference accuracy: mean Hellinger distance to exact (junction
//! tree) as a function of sample count for every sampling engine, normal
//! and rare evidence. The paper-shape claim: adaptive importance samplers
//! (AIS-BN, EPIS-BN) dominate under rare evidence.

use fastpgm::core::Evidence;
use fastpgm::inference::approx::{
    AisBn, ApproxOptions, EpisBn, LikelihoodWeighting, LogicSampling, SelfImportance,
};
use fastpgm::inference::exact::JunctionTree;
use fastpgm::inference::InferenceEngine;
use fastpgm::metrics::mean_hellinger;
use fastpgm::network::repository;

fn main() {
    println!("== E7: Hellinger distance vs sample count ==");
    let net = repository::asia();
    let jt = JunctionTree::build(&net);

    let scenarios = [
        (
            "normal evidence (xray=yes)",
            Evidence::new().with(net.var_index("xray").unwrap(), 1),
        ),
        (
            "rare evidence (tub=yes, xray=no, P≈3e-4)",
            Evidence::new()
                .with(net.var_index("tub").unwrap(), 1)
                .with(net.var_index("xray").unwrap(), 0),
        ),
    ];

    for (label, ev) in &scenarios {
        let truth = jt.engine().query_all(ev);
        println!("\n-- asia, {label} --");
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12} {:>12}",
            "samples", "pls", "lw", "sis", "ais-bn", "epis-bn"
        );
        for n in [1_000usize, 5_000, 20_000, 80_000] {
            let opts = ApproxOptions { n_samples: n, threads: 4, ..Default::default() };
            let h = |p: Vec<Vec<f64>>| mean_hellinger(&p, &truth);
            let pls = h(LogicSampling::new(&net, opts.clone()).query_all(ev));
            let lw = h(LikelihoodWeighting::new(&net, opts.clone()).query_all(ev));
            let sis = h(SelfImportance::new(&net, opts.clone()).query_all(ev));
            let ais = h(AisBn::new(&net, opts.clone()).query_all(ev));
            let epis = h(EpisBn::new(&net, opts).query_all(ev));
            println!(
                "{n:<10} {pls:>12.5} {lw:>12.5} {sis:>12.5} {ais:>12.5} {epis:>12.5}"
            );
        }
    }
    println!(
        "\nshape check: columns should decrease top-to-bottom (≈1/√n); under rare \
         evidence ais/epis < lw < pls."
    );
}
