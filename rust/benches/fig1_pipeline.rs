//! F1 — Figure 1 reproduction: the full Fast-PGM pipeline (data →
//! structure learning → parameter learning → exact + approximate
//! inference) with per-stage timings, on the small (survey) and medium
//! (child_like) workloads, sequential vs parallel.

use fastpgm::benchkit::{bench, fmt_duration, report};
use fastpgm::core::Evidence;
use fastpgm::inference::approx::{ApproxOptions, LikelihoodWeighting};
use fastpgm::inference::exact::{CalibrationMode, JunctionTree};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::{repository, synthetic::SyntheticSpec, BayesianNetwork};
use fastpgm::parameter::{mle, MleOptions};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{pc_stable_parallel, PcOptions};

fn pipeline(net: &BayesianNetwork, n_rows: usize, threads: usize) {
    println!(
        "\n-- pipeline on {} ({} vars), {} rows, {} thread(s) --",
        net.name(),
        net.n_vars(),
        n_rows,
        threads
    );
    let mut rng = Pcg::seed_from(31);
    let t0 = std::time::Instant::now();
    let data = forward_sample_dataset(net, n_rows, &mut rng);
    println!("  sample generation   {:>10}", fmt_duration(t0.elapsed()));

    let t0 = std::time::Instant::now();
    let pc = pc_stable_parallel(
        &data,
        &PcOptions { alpha: 0.05, threads, ..Default::default() },
    );
    println!(
        "  structure learning  {:>10}   ({} edges, {} CI tests)",
        fmt_duration(t0.elapsed()),
        pc.n_edges(),
        pc.n_tests
    );

    let t0 = std::time::Instant::now();
    let dag = pc.graph.to_dag().unwrap_or_else(|| net.dag().clone());
    let model = mle(&data, &dag, &MleOptions { threads, ..Default::default() });
    println!(
        "  parameter learning  {:>10}   ({} parameters)",
        fmt_duration(t0.elapsed()),
        model.n_parameters()
    );

    let ev = Evidence::new().with(0, 0);
    let t0 = std::time::Instant::now();
    let jt = JunctionTree::build(&model);
    let mode = if threads > 1 { CalibrationMode::Hybrid } else { CalibrationMode::Sequential };
    let mut engine = jt.parallel_engine(mode, threads);
    let _ = engine.query_all(&ev);
    println!(
        "  exact inference     {:>10}   ({} cliques, width {})",
        fmt_duration(t0.elapsed()),
        jt.cliques.len(),
        jt.max_clique_size()
    );

    let t0 = std::time::Instant::now();
    let opts = ApproxOptions { n_samples: 50_000, threads, ..Default::default() };
    let _ = LikelihoodWeighting::new(&model, opts).query_all(&ev);
    println!("  approx inference    {:>10}   (50k LW samples)", fmt_duration(t0.elapsed()));
}

fn main() {
    println!("== F1: Figure 1 pipeline, per-stage timings ==");
    let threads = fastpgm::parallel::default_threads().min(8);
    for net in [repository::survey(), SyntheticSpec::child_like().generate(1)] {
        pipeline(&net, 20_000, 1);
        pipeline(&net, 20_000, threads);
    }

    // End-to-end pipeline as one measured unit (seq vs parallel).
    let net = SyntheticSpec::child_like().generate(1);
    let rows: Vec<_> = [1usize, threads]
        .iter()
        .map(|&t| {
            bench(format!("child_like end-to-end, {t} thread(s)"), 0, 3, || {
                let mut rng = Pcg::seed_from(31);
                let data = forward_sample_dataset(&net, 10_000, &mut rng);
                let pc = pc_stable_parallel(
                    &data,
                    &PcOptions { alpha: 0.05, threads: t, ..Default::default() },
                );
                let dag = pc.graph.to_dag().unwrap_or_else(|| net.dag().clone());
                let model = mle(&data, &dag, &MleOptions { threads: t, ..Default::default() });
                let jt = JunctionTree::build(&model);
                jt.parallel_engine(CalibrationMode::Hybrid, t)
                    .query_all(&Evidence::new().with(0, 0))
            })
        })
        .collect();
    report("F1 end-to-end (sequential baseline first)", &rows);
}
