//! E6 — ablation of the sampling locality optimizations (opt vii):
//! fused inline accumulation (data fusion + reordering) vs two-pass
//! sample materialization, across network sizes.

use fastpgm::benchkit::{bench, report};
use fastpgm::core::Evidence;
use fastpgm::inference::approx::{ApproxOptions, LikelihoodWeighting, LogicSampling};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::synthetic::SyntheticSpec;

fn main() {
    println!("== E6: data fusion + reordering ablation (opt vii) ==");
    let n_samples = 100_000;
    for spec in [
        SyntheticSpec::child_like(),
        SyntheticSpec::alarm_like(),
        SyntheticSpec::hepar2_like(),
    ] {
        let net = spec.generate(1);
        let ev = Evidence::new().with(1, 0);
        let mk = |fusion: bool, threads: usize| ApproxOptions {
            n_samples,
            threads,
            fusion,
            ..Default::default()
        };
        let results = vec![
            bench(format!("{} LW materialized (no fusion)", net.name()), 1, 3, || {
                LikelihoodWeighting::new(&net, mk(false, 1)).query_all(&ev)
            }),
            bench(format!("{} LW fused (opt vii)", net.name()), 1, 3, || {
                LikelihoodWeighting::new(&net, mk(true, 1)).query_all(&ev)
            }),
            bench(format!("{} PLS materialized (no fusion)", net.name()), 1, 3, || {
                LogicSampling::new(&net, mk(false, 1)).query_all(&ev)
            }),
            bench(format!("{} PLS fused (opt vii)", net.name()), 1, 3, || {
                LogicSampling::new(&net, mk(true, 1)).query_all(&ev)
            }),
            bench(format!("{} LW fused x4 (vi+vii)", net.name()), 1, 3, || {
                LikelihoodWeighting::new(&net, mk(true, 4)).query_all(&ev)
            }),
        ];
        report(
            &format!("{} ({} vars, {} samples)", net.name(), net.n_vars(), n_samples),
            &results,
        );
    }
}
