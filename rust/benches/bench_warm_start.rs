//! Warm-start recalibration bench — the perf trajectory of the
//! evidence-delta serving path, written to `BENCH_warm_start.json`:
//!
//! * **cold vs warm latency vs delta size** — on networks with well over 8
//!   cliques, calibrate evidence `E = E' ∪ D` from scratch vs
//!   [`CompiledTree::recalibrate_from`] a base snapshot for `E'`, for
//!   `|D| ∈ {1, 2, 4}`. Warm recalibration skips the reset-and-absorb and
//!   the unchanged half of the collect pass, so it must beat cold on small
//!   deltas (the dashboard-panel case).
//! * **prefix-heavy trace** — a shuffled stream of nested evidence chains
//!   through the [`QueryEngine`] with warm starts on vs off: end-to-end
//!   time and the warm-start rate of the subset-aware cache.
//!
//! Every warm answer is cross-checked against cold calibration at 1e-12 —
//! the warm path must be numerically indistinguishable.

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, bench, fmt_duration, report};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::{CompiledTree, QueryEngine, QueryEngineConfig};
use fastpgm::network::repository;
use fastpgm::rng::Pcg;
use fastpgm::testkit;
use std::path::Path;
use std::time::Instant;

const DELTAS: [usize; 3] = [1, 2, 4];
const BASE_OBS: usize = 3;
const WARMUP: usize = 3;
const TRACE_CHAINS: usize = 8;
const TRACE_DEPTH: usize = 4;

fn main() {
    println!("== warm-start recalibration: evidence-delta message passing ==");
    // CI smoke-runs set FASTPGM_BENCH_QUICK=1: exercise every scenario and
    // correctness gate, emit the JSON artifact, skip the long sampling.
    let samples = benchkit::scaled(25, 3);
    let trace_queries = benchkit::scaled(512, 64);
    let mut scenarios: Vec<Json> = Vec::new();

    for (net_idx, name) in ["child_like", "alarm_like"].into_iter().enumerate() {
        let net_idx = net_idx as u64;
        let net = repository::by_name_extended(name).expect("known network");
        let compiled = CompiledTree::compile(&net);
        let n_cliques = compiled.tree().cliques.len();
        println!(
            "\n-- {name}: {} vars, {n_cliques} cliques, treewidth+1 = {} --",
            net.n_vars(),
            compiled.tree().max_clique_size()
        );
        assert!(n_cliques >= 8, "{name} too small for the delta sweep");

        // Draw evidence from one forward sample so every subset of it has
        // positive probability (warm and cold both do real work).
        let mut rng = Pcg::seed_from(0xA11CE + net_idx);
        let assignment = fastpgm::sampling::forward_sample(&net, &mut rng);
        let vars = rng.choose_k(net.n_vars(), BASE_OBS + DELTAS[DELTAS.len() - 1]);
        let base_ev: Evidence =
            vars[..BASE_OBS].iter().map(|&v| (v, assignment.get(v))).collect();
        let base_cal = compiled.calibrate(&base_ev);
        assert!(base_cal.evidence_probability() > 0.0, "degenerate base evidence");

        for &delta in &DELTAS {
            let full_ev: Evidence = vars[..BASE_OBS + delta]
                .iter()
                .map(|&v| (v, assignment.get(v)))
                .collect();

            // Correctness gate before timing anything.
            let warm_cal = compiled.recalibrate_from(&base_cal, &full_ev);
            let cold_cal = compiled.calibrate(&full_ev);
            let mut dev: f64 = 0.0;
            for (w, c) in warm_cal.posterior_all().iter().zip(&cold_cal.posterior_all())
            {
                for (a, b) in w.iter().zip(c) {
                    dev = dev.max((a - b).abs());
                }
            }
            assert!(
                dev <= 1e-12,
                "{name} delta {delta}: warm deviates from cold by {dev:.2e}"
            );

            let cold = bench(format!("{name} cold |D|={delta}"), WARMUP, samples, || {
                compiled.calibrate(&full_ev)
            });
            let warm = bench(format!("{name} warm |D|={delta}"), WARMUP, samples, || {
                compiled.recalibrate_from(&base_cal, &full_ev)
            });
            let speedup =
                cold.median().as_secs_f64() / warm.median().as_secs_f64().max(1e-12);
            report(
                &format!("{name}: base |E'|={BASE_OBS}, delta |D|={delta}"),
                &[cold.clone(), warm.clone()],
            );
            if speedup < 1.0 {
                println!("  WARNING: warm start slower than cold at |D|={delta}");
            }
            scenarios.push(Json::obj([
                ("net", Json::str(name)),
                ("mode", Json::str("delta_sweep")),
                ("n_cliques", Json::num(n_cliques as f64)),
                ("base_obs", Json::num(BASE_OBS as f64)),
                ("delta_obs", Json::num(delta as f64)),
                ("cold_median_us", Json::num(cold.median().as_secs_f64() * 1e6)),
                ("warm_median_us", Json::num(warm.median().as_secs_f64() * 1e6)),
                ("warm_speedup_vs_cold", Json::num(speedup)),
                ("max_abs_dev_vs_cold", Json::num(dev)),
            ]));
        }

        // Prefix-heavy trace through the QueryEngine: nested chains,
        // shuffled, repeated — the cache sees exact repeats (hits),
        // one-observation extensions (warm starts) and chain heads (cold).
        let mut rng = Pcg::seed_from(0xC0FFEE + net_idx);
        let pool =
            testkit::gen_evidence_chain_pool(&mut rng, &net, TRACE_CHAINS, TRACE_DEPTH);
        let trace: Vec<(Evidence, usize)> = (0..trace_queries)
            .map(|_| {
                let ev = pool[rng.below(pool.len())].clone();
                let var = testkit::gen_query_var(&mut rng, &net, &ev);
                (ev, var)
            })
            .collect();
        let mut results: Vec<(bool, f64, f64, f64)> = Vec::new();
        let mut answers: Vec<Vec<Vec<f64>>> = Vec::new();
        for warm_start in [false, true] {
            let engine = QueryEngine::with_config(
                &net,
                QueryEngineConfig::new().with_warm_start(warm_start).with_cache_capacity(64),
            );
            let t0 = Instant::now();
            let posts: Vec<Vec<f64>> =
                trace.iter().map(|(ev, var)| engine.posterior(*var, ev)).collect();
            let elapsed = t0.elapsed();
            let stats = engine.stats();
            println!(
                "  trace warm_start={warm_start}: {} for {trace_queries} queries \
                 (hit_rate={:.3}, warm_rate={:.3}, hits={} warm={} cold={})",
                fmt_duration(elapsed),
                stats.hit_rate(),
                stats.warm_start_rate(),
                stats.hits,
                stats.warm_starts,
                stats.cold_misses
            );
            results.push((
                warm_start,
                elapsed.as_secs_f64(),
                stats.hit_rate(),
                stats.warm_start_rate(),
            ));
            answers.push(posts);
        }
        // Warm and cold serving must answer the whole trace identically.
        let mut trace_dev: f64 = 0.0;
        for (a, b) in answers[0].iter().zip(&answers[1]) {
            for (x, y) in a.iter().zip(b) {
                trace_dev = trace_dev.max((x - y).abs());
            }
        }
        assert!(trace_dev <= 1e-12, "{name}: trace deviates by {trace_dev:.2e}");
        let cold_s = results[0].1;
        let warm_s = results[1].1;
        scenarios.push(Json::obj([
            ("net", Json::str(name)),
            ("mode", Json::str("prefix_trace")),
            ("queries", Json::num(trace_queries as f64)),
            ("pool", Json::num(pool.len() as f64)),
            ("cold_total_s", Json::num(cold_s)),
            ("warm_total_s", Json::num(warm_s)),
            ("trace_speedup", Json::num(cold_s / warm_s.max(1e-12))),
            ("warm_start_rate", Json::num(results[1].3)),
            ("hit_rate", Json::num(results[1].2)),
            ("max_abs_dev", Json::num(trace_dev)),
        ]));
    }

    let out = Json::obj([
        ("bench", Json::str("warm_start")),
        (
            "config",
            Json::obj([
                ("deltas", Json::Arr(DELTAS.iter().map(|&d| Json::num(d as f64)).collect())),
                ("base_obs", Json::num(BASE_OBS as f64)),
                ("samples", Json::num(samples as f64)),
                ("trace_queries", Json::num(trace_queries as f64)),
                ("trace_chains", Json::num(TRACE_CHAINS as f64)),
                ("trace_depth", Json::num(TRACE_DEPTH as f64)),
            ]),
        ),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = Path::new("BENCH_warm_start.json");
    benchkit::json::write(path, &out).expect("writing BENCH_warm_start.json");
    println!("\nwrote {}", path.display());
}
