//! E2 — ablation of the structure-learning memory/computation
//! optimizations: grouped single-pass contingency counting (opts ii+iii)
//! vs the naive four-pass baseline. Same graphs, same test counts —
//! only the data movement differs.

use fastpgm::benchkit::{bench, report};
use fastpgm::network::synthetic::SyntheticSpec;
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{pc_stable, CiTest, CiTester, CountStrategy, PcOptions};

fn main() {
    println!("== E2: counting-strategy ablation (opt ii + iii) ==");

    // Micro: a single level-2 CI test, where counting dominates.
    let net = SyntheticSpec::alarm_like().generate(1);
    let mut rng = Pcg::seed_from(2002);
    let data = forward_sample_dataset(&net, 50_000, &mut rng);
    let grouped = CiTester::with(&data, CiTest::GSquare, CountStrategy::Grouped);
    let naive = CiTester::with(&data, CiTest::GSquare, CountStrategy::Naive);
    let (x, y, z) = (0usize, 5usize, vec![2usize, 9]);
    let micro = vec![
        bench("single CI test, naive 4-pass", 3, 15, || naive.test(x, y, &z)),
        bench("single CI test, grouped 1-pass", 3, 15, || grouped.test(x, y, &z)),
    ];
    report("single conditional-independence test (50k rows)", &micro);

    // Macro: whole PC-stable run.
    for (label, rows) in [("insurance_like", 20_000usize), ("alarm_like", 20_000)] {
        let net = match label {
            "insurance_like" => SyntheticSpec::insurance_like().generate(1),
            _ => SyntheticSpec::alarm_like().generate(1),
        };
        let mut rng = Pcg::seed_from(2003);
        let data = forward_sample_dataset(&net, rows, &mut rng);
        let results = vec![
            bench(format!("{label} PC naive counting"), 1, 3, || {
                pc_stable(
                    &data,
                    &PcOptions { strategy: CountStrategy::Naive, ..Default::default() },
                )
            }),
            bench(format!("{label} PC grouped counting"), 1, 3, || {
                pc_stable(&data, &PcOptions::default())
            }),
        ];
        report(&format!("PC-stable on {label} ({rows} rows)"), &results);
    }
}
