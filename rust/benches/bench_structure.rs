//! E1 (Fast-BNS-style) — structure-learning speedup: sequential PC-stable
//! vs CI-level-parallel PC-stable across thread counts and network
//! scales. The paper-shape claim: near-linear scaling of the CI-test
//! phase, larger networks benefit more.

use fastpgm::benchkit::{bench, report, Measurement};
use fastpgm::network::{repository, synthetic::SyntheticSpec, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{pc_stable, pc_stable_parallel, PcOptions};

fn workload(net: &BayesianNetwork, rows: usize) -> fastpgm::core::Dataset {
    let mut rng = Pcg::seed_from(1001);
    forward_sample_dataset(net, rows, &mut rng)
}

fn main() {
    println!("== E1: PC-stable structure learning, threads sweep ==");
    let cores = fastpgm::parallel::default_threads();
    if cores <= 1 {
        println!(
            "NOTE: testbed exposes {cores} core(s); thread rows measure \
             scheduling overhead, not speedup (see EXPERIMENTS.md §Testbed)."
        );
    }
    let nets: Vec<BayesianNetwork> = vec![
        repository::survey(),
        SyntheticSpec::child_like().generate(1),
        SyntheticSpec::insurance_like().generate(1),
        SyntheticSpec::alarm_like().generate(1),
        SyntheticSpec::hepar2_like().generate(1),
    ];
    for net in &nets {
        let rows = 10_000;
        let data = workload(net, rows);
        let opts = PcOptions { alpha: 0.05, ..Default::default() };
        let mut results: Vec<Measurement> = Vec::new();
        results.push(bench(
            format!("{} seq", net.name()),
            1,
            3,
            || pc_stable(&data, &opts),
        ));
        for t in [2usize, 4, 8] {
            let popts = PcOptions { threads: t, ..opts.clone() };
            results.push(bench(
                format!("{} parallel x{t}", net.name()),
                1,
                3,
                || pc_stable_parallel(&data, &popts),
            ));
        }
        let r = pc_stable(&data, &opts);
        report(
            &format!(
                "{} ({} vars, {} rows, {} CI tests)",
                net.name(),
                net.n_vars(),
                rows,
                r.n_tests
            ),
            &results,
        );
    }
}
