//! Learning-pipeline bench — the substrate + parallel-learning numbers,
//! written to `BENCH_learning.json`:
//!
//! * **PC wall-clock and CI tests/s vs threads** — sequential PC-stable
//!   against the CI-level-parallel variant across worker counts (the
//!   paper's optimization (i) on the learning side).
//! * **Hill climbing sequential vs parallel** — the O(n²) candidate-delta
//!   scan fanned over the pool, with the thread-count-invariance gate
//!   asserted before anything is timed.
//! * **Count-cache effectiveness** — hit / projection / scan counters,
//!   hit rate and resident bytes of one shared cache carried across a
//!   full `learn::Pipeline` run (structure + MLE), plus the hit rate of
//!   a PC run alone.
//!
//! `FASTPGM_BENCH_QUICK=1` shrinks workloads for CI smoke runs.

use std::path::Path;

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, bench, report, throughput, Measurement};
use fastpgm::counts::CountCache;
use fastpgm::learn::Pipeline;
use fastpgm::network::{repository, synthetic::SyntheticSpec, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{
    hill_climb, pc_stable, pc_stable_parallel, pc_stable_with_cache, HcOptions,
    PcOptions,
};

fn workload(net: &BayesianNetwork, rows: usize) -> fastpgm::core::Dataset {
    let mut rng = Pcg::seed_from(0xC0FFEE);
    forward_sample_dataset(net, rows, &mut rng)
}

fn main() {
    println!("== learning pipeline: substrate + parallel learners ==");
    let rows = benchkit::scaled(20_000, 2_000);
    let pc_samples = benchkit::scaled(5, 2);
    let hc_samples = benchkit::scaled(3, 1);
    let thread_sweep: &[usize] =
        if benchkit::quick() { &[2] } else { &[2, 4, 8] };
    let mut scenarios: Vec<Json> = Vec::new();

    let nets: Vec<BayesianNetwork> = vec![
        repository::survey(),
        SyntheticSpec::child_like().generate(1),
    ];

    for net in &nets {
        let name = net.name().to_string();
        let data = workload(net, rows);
        let opts = PcOptions { alpha: 0.05, ..Default::default() };

        // Correctness gates before timing: parallel == sequential for
        // both learners, cache-backed == direct.
        let seq_result = pc_stable(&data, &opts);
        for &t in thread_sweep {
            let par =
                pc_stable_parallel(&data, &PcOptions { threads: t, ..opts.clone() });
            assert_eq!(seq_result.graph, par.graph, "{name}: PC diverges at t={t}");
            assert_eq!(seq_result.n_tests, par.n_tests);
        }
        let gate_cache = CountCache::new();
        let cached = pc_stable_with_cache(&data, &opts, &gate_cache);
        assert_eq!(seq_result.graph, cached.graph, "{name}: cache changes the graph");

        // PC wall-clock + CI tests/s vs threads.
        let mut rows_out: Vec<Measurement> = Vec::new();
        rows_out.push(bench(format!("{name} pc seq"), 1, pc_samples, || {
            pc_stable(&data, &opts)
        }));
        for &t in thread_sweep {
            let popts = PcOptions { threads: t, ..opts.clone() };
            rows_out.push(bench(format!("{name} pc x{t}"), 1, pc_samples, || {
                pc_stable_parallel(&data, &popts)
            }));
        }
        report(
            &format!("{name} PC-stable ({} vars, {rows} rows)", net.n_vars()),
            &rows_out,
        );
        let seq_median = rows_out[0].median();
        scenarios.push(Json::obj([
            ("net", Json::str(name.clone())),
            ("mode", Json::str("pc")),
            ("rows", Json::num(rows as f64)),
            ("n_ci_tests", Json::num(seq_result.n_tests as f64)),
            ("seq_median_us", Json::num(seq_median.as_secs_f64() * 1e6)),
            (
                "seq_ci_tests_per_s",
                Json::num(throughput(seq_result.n_tests, seq_median)),
            ),
            (
                "threads",
                Json::Arr(
                    thread_sweep
                        .iter()
                        .zip(rows_out.iter().skip(1))
                        .map(|(&t, m)| {
                            Json::obj([
                                ("threads", Json::num(t as f64)),
                                (
                                    "median_us",
                                    Json::num(m.median().as_secs_f64() * 1e6),
                                ),
                                (
                                    "ci_tests_per_s",
                                    Json::num(throughput(
                                        seq_result.n_tests,
                                        m.median(),
                                    )),
                                ),
                                (
                                    "speedup",
                                    Json::num(
                                        seq_median.as_secs_f64()
                                            / m.median().as_secs_f64().max(1e-12),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));

        // Hill climbing: sequential vs parallel candidate scan.
        let hc_seq_result = hill_climb(&data, &HcOptions::default());
        let hc_threads = benchkit::scaled(4, 2);
        let hc_par_result =
            hill_climb(&data, &HcOptions { threads: hc_threads, ..Default::default() });
        assert_eq!(
            hc_seq_result.dag.edges(),
            hc_par_result.dag.edges(),
            "{name}: parallel HC diverges"
        );
        let hc_rows = vec![
            bench(format!("{name} hc seq"), 0, hc_samples, || {
                hill_climb(&data, &HcOptions::default())
            }),
            bench(format!("{name} hc x{hc_threads}"), 0, hc_samples, || {
                hill_climb(&data, &HcOptions { threads: hc_threads, ..Default::default() })
            }),
        ];
        report(
            &format!(
                "{name} hill climbing ({} moves, score {:.1})",
                hc_seq_result.moves, hc_seq_result.score
            ),
            &hc_rows,
        );
        scenarios.push(Json::obj([
            ("net", Json::str(name.clone())),
            ("mode", Json::str("hc")),
            ("rows", Json::num(rows as f64)),
            ("moves", Json::num(hc_seq_result.moves as f64)),
            ("seq_median_us", Json::num(hc_rows[0].median().as_secs_f64() * 1e6)),
            ("par_threads", Json::num(hc_threads as f64)),
            ("par_median_us", Json::num(hc_rows[1].median().as_secs_f64() * 1e6)),
            (
                "par_speedup",
                Json::num(
                    hc_rows[0].median().as_secs_f64()
                        / hc_rows[1].median().as_secs_f64().max(1e-12),
                ),
            ),
        ]));

        // Count-cache effectiveness across one full pipeline run
        // (structure + MLE over a single shared cache), plus the PC-only
        // run's counters from the gate above. A CPDAG that fails to
        // extend on this sample (possible on small/quick workloads) only
        // skips the scenario, never the bench.
        match Pipeline::pc(opts.clone()).run(&data) {
            Ok(model) => {
                let c = &model.report.counts;
                let pc_only = gate_cache.stats();
                // Validation-overhead gate: the lifecycle validation pass
                // (`model::validate_network`, run on every load and every
                // registration) must stay noise against the learn itself —
                // under 3% of the pipeline's structure+MLE wall-clock.
                let validate = bench(format!("{name} validate"), 0, 30, || {
                    fastpgm::io::model::validate_network(&model.net).unwrap()
                });
                let learn_s = (model.report.structure_elapsed
                    + model.report.mle_elapsed)
                    .as_secs_f64();
                let overhead = validate.median().as_secs_f64() / learn_s.max(1e-9);
                println!(
                    "  {name} validation gate: {:.0?} vs learn {:.1?} \
                     ({:.3}% overhead)",
                    validate.median(),
                    model.report.structure_elapsed + model.report.mle_elapsed,
                    overhead * 100.0
                );
                assert!(
                    overhead < 0.03,
                    "{name}: validation overhead {:.2}% exceeds the 3% budget",
                    overhead * 100.0
                );
                println!(
                    "  {name} count cache (pipeline): hits={} projections={} \
                     scans={} hit_rate={:.3} scan_free={:.3} bytes={}",
                    c.hits,
                    c.projections,
                    c.scans,
                    c.hit_rate(),
                    c.scan_free_rate(),
                    c.bytes
                );
                scenarios.push(Json::obj([
                    ("net", Json::str(name.clone())),
                    ("mode", Json::str("count_cache")),
                    ("pipeline_hits", Json::num(c.hits as f64)),
                    ("pipeline_projections", Json::num(c.projections as f64)),
                    ("pipeline_scans", Json::num(c.scans as f64)),
                    ("pipeline_hit_rate", Json::num(c.hit_rate())),
                    ("pipeline_scan_free_rate", Json::num(c.scan_free_rate())),
                    ("pipeline_bytes", Json::num(c.bytes as f64)),
                    ("pipeline_tables", Json::num(c.tables as f64)),
                    ("pc_only_hit_rate", Json::num(pc_only.hit_rate())),
                    (
                        "mle_elapsed_us",
                        Json::num(model.report.mle_elapsed.as_secs_f64() * 1e6),
                    ),
                    (
                        "validate_median_us",
                        Json::num(validate.median().as_secs_f64() * 1e6),
                    ),
                    ("validate_overhead_frac", Json::num(overhead)),
                ]));
            }
            Err(e) => println!("  {name} pipeline scenario skipped: {e}"),
        }
    }

    let out = Json::obj([
        ("bench", Json::str("learning")),
        (
            "config",
            Json::obj([
                ("rows", Json::num(rows as f64)),
                ("pc_samples", Json::num(pc_samples as f64)),
                ("hc_samples", Json::num(hc_samples as f64)),
                ("quick", Json::num(if benchkit::quick() { 1.0 } else { 0.0 })),
            ]),
        ),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = Path::new("BENCH_learning.json");
    benchkit::json::write(path, &out).expect("writing BENCH_learning.json");
    println!("\nwrote {}", path.display());
}
