//! Fabric bench — what does crossing the wire cost, and what does
//! affinity routing buy? Four serving shapes over the same prefix-heavy
//! query trace, written to `BENCH_fabric.json`:
//!
//! * `in-process`         — the [`QueryRouter`] baseline, no wire.
//! * `fabric-1`           — one shard behind the versioned wire protocol
//!   (isolates pure framing + TCP round-trip overhead).
//! * `fabric-N-affinity`  — N shards, consistent hashing on the evidence
//!   signature prefix (nested chains stay colocated, caches stay warm).
//! * `fabric-N-rr`        — N shards, round-robin (the ablation: same
//!   wire, no locality — watch the warm-start rate fall).
//!
//! Shards run in-process over real TCP ([`ThreadLauncher`]), so the wire
//! traffic is identical to `serve-query --fabric N` without needing the
//! built binary on the bench path.
//!
//! Two resilience gates ride along (docs/ROBUSTNESS.md):
//!
//! * `faults-idle`        — an armed fault plan whose rules never fire
//!   (prob 0) must cost < 5% vs no plan on the affinity hot path
//!   (bench_obs methodology: interleaved rounds, best round per arm; the
//!   assert is skipped under `FASTPGM_BENCH_QUICK=1`).
//! * `straggler-hedged`   — with shard 0 serving 20 ms slow, hedged sends
//!   must cut interactive p99 vs the unhedged run of the same trace.

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, report, scaled, Measurement};
use fastpgm::core::Evidence;
use fastpgm::network::{repository, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::serving::{
    Backoff, FabricConfig, FaultKind, FaultPlan, FaultRule, FaultSite, Frontend,
    ModelSpec, QueryEngineConfig, QueryRequest, QueryRouter, RoutingPolicy,
    ShardConfig, ThreadLauncher,
};
use fastpgm::testkit;
use std::path::Path;
use std::time::{Duration, Instant};

const MODEL: &str = "alarm_like";
const SHARDS: usize = 2;
const CACHE_CAPACITY: usize = 256;
/// Interleaved rounds for the faults-idle comparison (best round per arm).
const FAULT_ROUNDS: usize = 3;

fn specs(net: &BayesianNetwork) -> Vec<ModelSpec> {
    vec![ModelSpec::new(MODEL, net.clone())
        .with_engine(QueryEngineConfig::new().with_cache_capacity(CACHE_CAPACITY))]
}

/// Prefix-heavy trace: nested evidence chains in serving order (the
/// traffic shape whose warm starts affinity routing is built to protect).
fn workload(net: &BayesianNetwork, queries: usize) -> Vec<(usize, Evidence)> {
    let mut rng = Pcg::seed_from(0xFAB);
    let chains = (queries / 4).max(1);
    let pool = testkit::gen_evidence_chain_pool(&mut rng, net, chains, 4);
    (0..queries)
        .map(|i| {
            let ev = pool[i % pool.len()].clone();
            (testkit::gen_query_var(&mut rng, net, &ev), ev)
        })
        .collect()
}

fn drive(
    trace: &[(usize, Evidence)],
    mut answer: impl FnMut(usize, &Evidence) -> Vec<f64>,
) -> (Vec<Vec<f64>>, Vec<Duration>) {
    let mut posts = Vec::with_capacity(trace.len());
    let mut latencies = Vec::with_capacity(trace.len());
    for (var, ev) in trace {
        let t0 = Instant::now();
        let p = answer(*var, ev);
        latencies.push(t0.elapsed());
        posts.push(p);
    }
    (posts, latencies)
}

/// Run the trace through a thread-shard fabric; returns posteriors,
/// latencies, and the fleet warm-start rate off the wire stats.
fn run_fabric(
    net: &BayesianNetwork,
    shards: usize,
    policy: RoutingPolicy,
    trace: &[(usize, Evidence)],
) -> (Vec<Vec<f64>>, Vec<Duration>, f64) {
    let frontend = Frontend::new(
        specs(net),
        Box::new(
            ThreadLauncher::new(specs(net))
                .with_config(ShardConfig::new().with_pool_threads(2)),
        ),
        FabricConfig::new().with_shards(shards).with_policy(policy),
    )
    .expect("fabric launches");
    let (posts, latencies) = drive(trace, |var, ev| {
        frontend
            .query_routed(MODEL, QueryRequest::marginal(var, ev.clone()))
            .expect("fabric answers")
            .into_marginal()
            .expect("marginal reply")
    });
    let stats = frontend.stats().expect("fleet stats");
    let warm_rate = stats
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, s)| s.cache.warm_start_rate())
        .unwrap_or(0.0);
    frontend.shutdown();
    (posts, latencies, warm_rate)
}

/// Run the trace through a fabric with explicit fault wiring on the shard
/// side (`shard_plan`) and whatever the caller put in `config` (frontend
/// plan, hedging, backoff); returns per-query latencies.
fn run_with(
    net: &BayesianNetwork,
    trace: &[(usize, Evidence)],
    shard_plan: Option<FaultPlan>,
    config: FabricConfig,
) -> Vec<Duration> {
    let mut shard_config = ShardConfig::new().with_pool_threads(2);
    if let Some(plan) = shard_plan {
        shard_config = shard_config.with_faults(plan);
    }
    let frontend = Frontend::new(
        specs(net),
        Box::new(ThreadLauncher::new(specs(net)).with_config(shard_config)),
        config,
    )
    .expect("fabric launches");
    let (_, latencies) = drive(trace, |var, ev| {
        frontend
            .query_routed(MODEL, QueryRequest::marginal(var, ev.clone()))
            .expect("fabric answers")
            .into_marginal()
            .expect("marginal reply")
    });
    frontend.shutdown();
    latencies
}

fn scenario_json(mode: &str, latencies: &[Duration], extra: Vec<(&str, Json)>) -> Json {
    let total: f64 = latencies.iter().map(Duration::as_secs_f64).sum();
    let m = Measurement { label: mode.to_string(), samples: latencies.to_vec() };
    let mut pairs = vec![
        ("net", Json::str(MODEL)),
        ("mode", Json::str(mode)),
        ("queries", Json::num(latencies.len() as f64)),
        ("throughput_qps", Json::num(latencies.len() as f64 / total.max(1e-12))),
        ("p50_us", Json::num(m.percentile(50.0).as_secs_f64() * 1e6)),
        ("p99_us", Json::num(m.percentile(99.0).as_secs_f64() * 1e6)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

fn main() {
    let queries = scaled(512, 96);
    println!(
        "== fabric: in-process vs wire, affinity vs round-robin \
         ({MODEL}, {queries} queries, {SHARDS} shards) =="
    );
    let net = repository::by_name_extended(MODEL).expect("known network");
    let trace = workload(&net, queries);

    // 1. In-process baseline (no wire anywhere).
    let mut router = QueryRouter::new(2);
    for spec in specs(&net) {
        router.register_with_approx(
            spec.name.as_str(),
            &spec.net,
            spec.engine,
            spec.batcher.clone(),
            spec.approx.clone(),
        );
    }
    let (local_posts, local_lat) = drive(&trace, |var, ev| {
        router
            .query_routed(MODEL, QueryRequest::marginal(var, ev.clone()))
            .expect("router answers")
            .into_marginal()
            .expect("marginal reply")
    });
    let local_warm = router.stats()[0].1.cache.warm_start_rate();

    // 2. One shard: pure wire overhead. 3./4. N shards: affinity vs rr.
    let (one_posts, one_lat, one_warm) =
        run_fabric(&net, 1, RoutingPolicy::Affinity, &trace);
    let (aff_posts, aff_lat, aff_warm) =
        run_fabric(&net, SHARDS, RoutingPolicy::Affinity, &trace);
    let (_rr_posts, rr_lat, rr_warm) =
        run_fabric(&net, SHARDS, RoutingPolicy::RoundRobin, &trace);

    // The wire must not change a single answer (f64s cross bit-exact).
    for ((a, b), c) in local_posts.iter().zip(&one_posts).zip(&aff_posts) {
        for ((x, y), z) in a.iter().zip(b).zip(c) {
            assert!(
                (x - y).abs() <= 1e-12 && (x - z).abs() <= 1e-12,
                "fabric answers diverged from in-process serving"
            );
        }
    }

    let rows = [
        ("in-process", &local_lat),
        ("fabric 1 shard", &one_lat),
        ("fabric N affinity", &aff_lat),
        ("fabric N round-robin", &rr_lat),
    ]
    .map(|(label, samples)| Measurement {
        label: label.to_string(),
        samples: samples.clone(),
    });
    report(&format!("{MODEL} ({} vars, {queries} queries)", net.n_vars()), &rows);
    println!(
        "  warm-start rates: in-process {local_warm:.3}, 1-shard {one_warm:.3}, \
         {SHARDS}-shard affinity {aff_warm:.3}, {SHARDS}-shard rr {rr_warm:.3}"
    );
    if local_warm - aff_warm > 0.10 {
        println!("  WARNING: affinity warm rate fell >10% below in-process");
    }

    // 5. Faults-idle gate: an armed plan whose rules never fire (prob 0 on
    //    both the shard and frontend hooks) vs no plan at all, same trace,
    //    same affinity fabric. Interleaved rounds so background-load drift
    //    hits both arms equally; keep the best (least-perturbed) round.
    let idle_plan = FaultPlan::seeded(1)
        .with(FaultKind::Delay, 0.0, FaultSite::Serve)
        .with(FaultKind::Corrupt, 0.0, FaultSite::ShardSend)
        .with(FaultKind::Refuse, 0.0, FaultSite::Connect);
    let mut best: [Option<Vec<Duration>>; 2] = [None, None];
    for _ in 0..FAULT_ROUNDS {
        for (arm, slot) in best.iter_mut().enumerate() {
            let plan = (arm == 1).then(|| idle_plan.clone());
            let mut config =
                FabricConfig::new().with_shards(SHARDS).with_policy(RoutingPolicy::Affinity);
            if let Some(p) = plan.clone() {
                config = config.with_faults(p);
            }
            let lat = run_with(&net, &trace, plan, config);
            let total: Duration = lat.iter().sum();
            let keep = match slot {
                Some(prev) => total < prev.iter().sum::<Duration>(),
                None => true,
            };
            if keep {
                *slot = Some(lat);
            }
        }
    }
    let hooks_off = best[0].take().expect("rounds ran");
    let hooks_idle = best[1].take().expect("rounds ran");
    let idle_ratio = hooks_idle.iter().sum::<Duration>().as_secs_f64()
        / hooks_off.iter().sum::<Duration>().as_secs_f64().max(1e-12);
    println!(
        "  fault hooks: no plan vs armed idle plan ratio {idle_ratio:.3} (gate < 1.05)"
    );

    // 6. Hedged sends vs a straggler: shard 0 answers 20 ms slow, every
    //    query. Round-robin sends half the trace straight at it; hedging
    //    cuts the primary read at 2 ms and retries the ring successor.
    let straggler_trace = workload(&net, scaled(192, 48));
    let straggler = |hedge: bool| {
        let plan = FaultPlan::seeded(7).with_rule(FaultRule {
            kind: FaultKind::Delay,
            prob: 1.0,
            site: FaultSite::Serve,
            shard: Some(0),
            millis: 20,
        });
        let mut config = FabricConfig::new()
            .with_shards(SHARDS)
            .with_policy(RoutingPolicy::RoundRobin)
            .with_backoff(Backoff::new().with_base(Duration::from_millis(1)));
        if hedge {
            config = config.with_hedge(true).with_hedge_delay(Duration::from_millis(2));
        }
        run_with(&net, &straggler_trace, Some(plan), config)
    };
    let no_hedge_lat = straggler(false);
    let hedged_lat = straggler(true);
    let p99 = |lat: &[Duration]| {
        Measurement { label: String::new(), samples: lat.to_vec() }
            .percentile(99.0)
            .as_secs_f64()
            * 1e6
    };
    let (p99_off, p99_on) = (p99(&no_hedge_lat), p99(&hedged_lat));
    println!(
        "  straggler p99: unhedged {p99_off:.0}us, hedged {p99_on:.0}us \
         (hedge must win)"
    );

    let out = Json::obj([
        ("bench", Json::str("fabric")),
        (
            "config",
            Json::obj([
                ("net", Json::str(MODEL)),
                ("queries", Json::num(queries as f64)),
                ("shards", Json::num(SHARDS as f64)),
                ("cache_capacity", Json::num(CACHE_CAPACITY as f64)),
            ]),
        ),
        (
            "scenarios",
            Json::Arr(vec![
                scenario_json(
                    "in-process",
                    &local_lat,
                    vec![("warm_start_rate", Json::num(local_warm))],
                ),
                scenario_json(
                    "fabric-1",
                    &one_lat,
                    vec![("warm_start_rate", Json::num(one_warm)), ("shards", Json::num(1.0))],
                ),
                scenario_json(
                    "fabric-affinity",
                    &aff_lat,
                    vec![
                        ("warm_start_rate", Json::num(aff_warm)),
                        ("shards", Json::num(SHARDS as f64)),
                        ("warm_rate_vs_in_process", Json::num(aff_warm - local_warm)),
                    ],
                ),
                scenario_json(
                    "fabric-rr",
                    &rr_lat,
                    vec![
                        ("warm_start_rate", Json::num(rr_warm)),
                        ("shards", Json::num(SHARDS as f64)),
                        ("warm_rate_vs_in_process", Json::num(rr_warm - local_warm)),
                    ],
                ),
                scenario_json(
                    "faults-idle",
                    &hooks_idle,
                    vec![
                        ("idle_overhead_ratio", Json::num(idle_ratio)),
                        ("gate", Json::num(1.05)),
                    ],
                ),
                scenario_json(
                    "straggler-no-hedge",
                    &no_hedge_lat,
                    vec![("hedge", Json::num(0.0)), ("injected_delay_ms", Json::num(20.0))],
                ),
                scenario_json(
                    "straggler-hedged",
                    &hedged_lat,
                    vec![
                        ("hedge", Json::num(1.0)),
                        ("injected_delay_ms", Json::num(20.0)),
                        ("p99_improvement_us", Json::num(p99_off - p99_on)),
                    ],
                ),
            ]),
        ),
        ("quick", Json::num(if benchkit::quick() { 1.0 } else { 0.0 })),
    ]);
    let path = Path::new("BENCH_fabric.json");
    benchkit::json::write(path, &out).expect("writing BENCH_fabric.json");
    println!("\nwrote {}", path.display());

    // The gates. Quick (CI smoke) runs are too noisy for a 5% latency
    // comparison or a p99 race to be meaningful — emit, don't assert.
    if !benchkit::quick() {
        assert!(
            idle_ratio < 1.05,
            "armed-but-idle fault hooks cost {:.1}% (> 5% gate)",
            (idle_ratio - 1.0) * 100.0
        );
        assert!(
            p99_on < p99_off,
            "hedged p99 {p99_on:.0}us did not beat unhedged {p99_off:.0}us \
             under a 20ms straggler"
        );
    } else if idle_ratio >= 1.05 || p99_on >= p99_off {
        println!(
            "  NOTE: resilience gates outside bounds in quick mode (noisy; \
             asserted in full runs only)"
        );
    }
}
