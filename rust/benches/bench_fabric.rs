//! Fabric bench — what does crossing the wire cost, and what does
//! affinity routing buy? Four serving shapes over the same prefix-heavy
//! query trace, written to `BENCH_fabric.json`:
//!
//! * `in-process`         — the [`QueryRouter`] baseline, no wire.
//! * `fabric-1`           — one shard behind the versioned wire protocol
//!   (isolates pure framing + TCP round-trip overhead).
//! * `fabric-N-affinity`  — N shards, consistent hashing on the evidence
//!   signature prefix (nested chains stay colocated, caches stay warm).
//! * `fabric-N-rr`        — N shards, round-robin (the ablation: same
//!   wire, no locality — watch the warm-start rate fall).
//!
//! Shards run in-process over real TCP ([`ThreadLauncher`]), so the wire
//! traffic is identical to `serve-query --fabric N` without needing the
//! built binary on the bench path.

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, report, scaled, Measurement};
use fastpgm::core::Evidence;
use fastpgm::network::{repository, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::serving::{
    FabricConfig, Frontend, ModelSpec, QueryEngineConfig, QueryRequest, QueryRouter,
    RoutingPolicy, ShardConfig, ThreadLauncher,
};
use fastpgm::testkit;
use std::path::Path;
use std::time::{Duration, Instant};

const MODEL: &str = "alarm_like";
const SHARDS: usize = 2;
const CACHE_CAPACITY: usize = 256;

fn specs(net: &BayesianNetwork) -> Vec<ModelSpec> {
    vec![ModelSpec::new(MODEL, net.clone())
        .with_engine(QueryEngineConfig::new().with_cache_capacity(CACHE_CAPACITY))]
}

/// Prefix-heavy trace: nested evidence chains in serving order (the
/// traffic shape whose warm starts affinity routing is built to protect).
fn workload(net: &BayesianNetwork, queries: usize) -> Vec<(usize, Evidence)> {
    let mut rng = Pcg::seed_from(0xFAB);
    let chains = (queries / 4).max(1);
    let pool = testkit::gen_evidence_chain_pool(&mut rng, net, chains, 4);
    (0..queries)
        .map(|i| {
            let ev = pool[i % pool.len()].clone();
            (testkit::gen_query_var(&mut rng, net, &ev), ev)
        })
        .collect()
}

fn drive(
    trace: &[(usize, Evidence)],
    mut answer: impl FnMut(usize, &Evidence) -> Vec<f64>,
) -> (Vec<Vec<f64>>, Vec<Duration>) {
    let mut posts = Vec::with_capacity(trace.len());
    let mut latencies = Vec::with_capacity(trace.len());
    for (var, ev) in trace {
        let t0 = Instant::now();
        let p = answer(*var, ev);
        latencies.push(t0.elapsed());
        posts.push(p);
    }
    (posts, latencies)
}

/// Run the trace through a thread-shard fabric; returns posteriors,
/// latencies, and the fleet warm-start rate off the wire stats.
fn run_fabric(
    net: &BayesianNetwork,
    shards: usize,
    policy: RoutingPolicy,
    trace: &[(usize, Evidence)],
) -> (Vec<Vec<f64>>, Vec<Duration>, f64) {
    let frontend = Frontend::new(
        specs(net),
        Box::new(
            ThreadLauncher::new(specs(net))
                .with_config(ShardConfig::new().with_pool_threads(2)),
        ),
        FabricConfig::new().with_shards(shards).with_policy(policy),
    )
    .expect("fabric launches");
    let (posts, latencies) = drive(trace, |var, ev| {
        frontend
            .query_routed(MODEL, QueryRequest::marginal(var, ev.clone()))
            .expect("fabric answers")
            .into_marginal()
            .expect("marginal reply")
    });
    let stats = frontend.stats().expect("fleet stats");
    let warm_rate = stats
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, s)| s.cache.warm_start_rate())
        .unwrap_or(0.0);
    frontend.shutdown();
    (posts, latencies, warm_rate)
}

fn scenario_json(mode: &str, latencies: &[Duration], extra: Vec<(&str, Json)>) -> Json {
    let total: f64 = latencies.iter().map(Duration::as_secs_f64).sum();
    let m = Measurement { label: mode.to_string(), samples: latencies.to_vec() };
    let mut pairs = vec![
        ("net", Json::str(MODEL)),
        ("mode", Json::str(mode)),
        ("queries", Json::num(latencies.len() as f64)),
        ("throughput_qps", Json::num(latencies.len() as f64 / total.max(1e-12))),
        ("p50_us", Json::num(m.percentile(50.0).as_secs_f64() * 1e6)),
        ("p99_us", Json::num(m.percentile(99.0).as_secs_f64() * 1e6)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

fn main() {
    let queries = scaled(512, 96);
    println!(
        "== fabric: in-process vs wire, affinity vs round-robin \
         ({MODEL}, {queries} queries, {SHARDS} shards) =="
    );
    let net = repository::by_name_extended(MODEL).expect("known network");
    let trace = workload(&net, queries);

    // 1. In-process baseline (no wire anywhere).
    let mut router = QueryRouter::new(2);
    for spec in specs(&net) {
        router.register_with_approx(
            spec.name.as_str(),
            &spec.net,
            spec.engine,
            spec.batcher.clone(),
            spec.approx.clone(),
        );
    }
    let (local_posts, local_lat) = drive(&trace, |var, ev| {
        router
            .query_routed(MODEL, QueryRequest::marginal(var, ev.clone()))
            .expect("router answers")
            .into_marginal()
            .expect("marginal reply")
    });
    let local_warm = router.stats()[0].1.cache.warm_start_rate();

    // 2. One shard: pure wire overhead. 3./4. N shards: affinity vs rr.
    let (one_posts, one_lat, one_warm) =
        run_fabric(&net, 1, RoutingPolicy::Affinity, &trace);
    let (aff_posts, aff_lat, aff_warm) =
        run_fabric(&net, SHARDS, RoutingPolicy::Affinity, &trace);
    let (_rr_posts, rr_lat, rr_warm) =
        run_fabric(&net, SHARDS, RoutingPolicy::RoundRobin, &trace);

    // The wire must not change a single answer (f64s cross bit-exact).
    for ((a, b), c) in local_posts.iter().zip(&one_posts).zip(&aff_posts) {
        for ((x, y), z) in a.iter().zip(b).zip(c) {
            assert!(
                (x - y).abs() <= 1e-12 && (x - z).abs() <= 1e-12,
                "fabric answers diverged from in-process serving"
            );
        }
    }

    let rows = [
        ("in-process", &local_lat),
        ("fabric 1 shard", &one_lat),
        ("fabric N affinity", &aff_lat),
        ("fabric N round-robin", &rr_lat),
    ]
    .map(|(label, samples)| Measurement {
        label: label.to_string(),
        samples: samples.clone(),
    });
    report(&format!("{MODEL} ({} vars, {queries} queries)", net.n_vars()), &rows);
    println!(
        "  warm-start rates: in-process {local_warm:.3}, 1-shard {one_warm:.3}, \
         {SHARDS}-shard affinity {aff_warm:.3}, {SHARDS}-shard rr {rr_warm:.3}"
    );
    if local_warm - aff_warm > 0.10 {
        println!("  WARNING: affinity warm rate fell >10% below in-process");
    }

    let out = Json::obj([
        ("bench", Json::str("fabric")),
        (
            "config",
            Json::obj([
                ("net", Json::str(MODEL)),
                ("queries", Json::num(queries as f64)),
                ("shards", Json::num(SHARDS as f64)),
                ("cache_capacity", Json::num(CACHE_CAPACITY as f64)),
            ]),
        ),
        (
            "scenarios",
            Json::Arr(vec![
                scenario_json(
                    "in-process",
                    &local_lat,
                    vec![("warm_start_rate", Json::num(local_warm))],
                ),
                scenario_json(
                    "fabric-1",
                    &one_lat,
                    vec![("warm_start_rate", Json::num(one_warm)), ("shards", Json::num(1.0))],
                ),
                scenario_json(
                    "fabric-affinity",
                    &aff_lat,
                    vec![
                        ("warm_start_rate", Json::num(aff_warm)),
                        ("shards", Json::num(SHARDS as f64)),
                        ("warm_rate_vs_in_process", Json::num(aff_warm - local_warm)),
                    ],
                ),
                scenario_json(
                    "fabric-rr",
                    &rr_lat,
                    vec![
                        ("warm_start_rate", Json::num(rr_warm)),
                        ("shards", Json::num(SHARDS as f64)),
                        ("warm_rate_vs_in_process", Json::num(rr_warm - local_warm)),
                    ],
                ),
            ]),
        ),
    ]);
    let path = Path::new("BENCH_fabric.json");
    benchkit::json::write(path, &out).expect("writing BENCH_fabric.json");
    println!("\nwrote {}", path.display());
}
