//! E3 (Fast-BNI-style) — exact-inference speedup: junction-tree
//! calibration sequential vs inter-clique vs hybrid parallelism across
//! thread counts and network scales; variable elimination for reference.

use fastpgm::benchkit::{bench, report, Measurement};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::{CalibrationMode, JunctionTree, VariableElimination};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::{repository, synthetic::SyntheticSpec, BayesianNetwork};
use fastpgm::rng::Pcg;

fn random_evidence(net: &BayesianNetwork, k: usize, seed: u64) -> Evidence {
    let mut rng = Pcg::seed_from(seed);
    rng.choose_k(net.n_vars(), k)
        .into_iter()
        .map(|v| (v, rng.below(net.cardinality(v))))
        .collect()
}

fn main() {
    println!("== E3: junction-tree calibration, parallelism sweep ==");
    if fastpgm::parallel::default_threads() <= 1 {
        println!("NOTE: 1-core testbed; thread rows measure overhead, not speedup.");
    }
    let nets: Vec<BayesianNetwork> = vec![
        repository::asia(),
        SyntheticSpec::child_like().generate(1),
        SyntheticSpec::insurance_like().generate(1),
        SyntheticSpec::alarm_like().generate(1),
        SyntheticSpec::hepar2_like().generate(1),
        SyntheticSpec::win95pts_like().generate(1),
    ];
    for net in &nets {
        let jt = JunctionTree::build(net);
        let ev = random_evidence(net, 3, 77);
        let mut results: Vec<Measurement> = Vec::new();

        let mut seq = jt.engine();
        results.push(bench(format!("{} JT seq", net.name()), 1, 5, || {
            seq.calibrate(&Evidence::new());
            seq.calibrate(&ev);
            seq.evidence_probability()
        }));
        for mode in [CalibrationMode::InterClique, CalibrationMode::Hybrid] {
            for t in [2usize, 4] {
                let mut eng = jt.parallel_engine(mode, t);
                let ev = ev.clone();
                results.push(bench(
                    format!("{} JT {mode:?} x{t}", net.name()),
                    1,
                    5,
                    move || {
                        eng.calibrate(&Evidence::new());
                        eng.calibrate(&ev.clone());
                        eng.evidence_probability()
                    },
                ));
            }
        }
        // VE reference (single full query_all).
        if net.n_vars() <= 40 {
            let ev2 = random_evidence(net, 3, 77);
            let mut ve = VariableElimination::new(net);
            results.push(bench(format!("{} VE (reference)", net.name()), 1, 3, move || {
                ve.query_all(&ev2)
            }));
        }
        report(
            &format!(
                "{} ({} vars, {} cliques, width {}, {} states)",
                net.name(),
                net.n_vars(),
                jt.cliques.len(),
                jt.max_clique_size(),
                jt.total_states()
            ),
            &results,
        );
    }
}
