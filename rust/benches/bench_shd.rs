//! E8 — learning quality: structural Hamming distance (vs the true
//! CPDAG) and skeleton precision/recall as a function of sample size,
//! plus CI-test counts (the work the parallel scheme distributes).

use fastpgm::metrics::{shd_vs_dag_cpdag, skeleton_prf};
use fastpgm::network::{repository, synthetic::SyntheticSpec, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{pc_stable_parallel, PcOptions};

fn sweep(net: &BayesianNetwork) {
    println!(
        "\n-- {} ({} vars, {} true edges) --",
        net.name(),
        net.n_vars(),
        net.dag().n_edges()
    );
    println!(
        "{:<10} {:>6} {:>8} {:>8} {:>8} {:>10} {:>10}",
        "samples", "SHD", "prec", "recall", "F1", "CI tests", "time"
    );
    for n in [1_000usize, 5_000, 20_000, 50_000] {
        let mut rng = Pcg::seed_from(808);
        let data = forward_sample_dataset(net, n, &mut rng);
        let t0 = std::time::Instant::now();
        let r = pc_stable_parallel(
            &data,
            &PcOptions { alpha: 0.05, threads: 4, ..Default::default() },
        );
        let elapsed = t0.elapsed();
        let shd = shd_vs_dag_cpdag(&r.graph, net.dag());
        let (p, rec, f1) = skeleton_prf(&r.graph, net.dag());
        println!(
            "{n:<10} {shd:>6} {p:>8.3} {rec:>8.3} {f1:>8.3} {:>10} {:>10}",
            r.n_tests,
            fastpgm::benchkit::fmt_duration(elapsed)
        );
    }
}

fn main() {
    println!("== E8: SHD / skeleton quality vs sample size ==");
    sweep(&repository::survey());
    sweep(&SyntheticSpec::child_like().generate(1));
    sweep(&SyntheticSpec::insurance_like().generate(1));
    sweep(&SyntheticSpec::alarm_like().generate(1));
    println!("\nshape check: SHD falls and F1 rises with more samples.");
}
