//! E5 (ATC-style) — approximate-inference speedup: all six algorithms
//! with sample-level parallelism (opt vi) across thread counts on the
//! alarm-scale workload.

use fastpgm::benchkit::{bench, report, Measurement};
use fastpgm::core::Evidence;
use fastpgm::inference::approx::{
    AisBn, ApproxOptions, EpisBn, LikelihoodWeighting, LogicSampling, LoopyBp,
    LoopyBpOptions, SelfImportance,
};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::synthetic::SyntheticSpec;
use fastpgm::rng::Pcg;

fn main() {
    println!("== E5: approximate inference, sample-level parallelism ==");
    if fastpgm::parallel::default_threads() <= 1 {
        println!("NOTE: 1-core testbed; thread rows measure overhead, not speedup.");
    }
    let net = SyntheticSpec::alarm_like().generate(1);
    let mut rng = Pcg::seed_from(5005);
    let ev: Evidence = rng
        .choose_k(net.n_vars(), 4)
        .into_iter()
        .map(|v| (v, rng.below(net.cardinality(v))))
        .collect();
    let n_samples = 50_000;

    let threads_sweep: Vec<usize> = vec![1, 2, 4];

    type Runner<'a> = Box<dyn Fn(usize) -> Vec<Vec<f64>> + 'a>;
    let engines: Vec<(&str, Runner)> = vec![
        ("logic-sampling", Box::new(|t| {
            LogicSampling::new(&net, ApproxOptions { n_samples, threads: t, ..Default::default() })
                .query_all(&ev)
        })),
        ("likelihood-weighting", Box::new(|t| {
            let opts = ApproxOptions { n_samples, threads: t, ..Default::default() };
            LikelihoodWeighting::new(&net, opts).query_all(&ev)
        })),
        ("self-importance", Box::new(|t| {
            SelfImportance::new(&net, ApproxOptions { n_samples, threads: t, ..Default::default() })
                .query_all(&ev)
        })),
        ("ais-bn", Box::new(|t| {
            AisBn::new(&net, ApproxOptions { n_samples, threads: t, ..Default::default() })
                .query_all(&ev)
        })),
        ("epis-bn", Box::new(|t| {
            EpisBn::new(&net, ApproxOptions { n_samples, threads: t, ..Default::default() })
                .query_all(&ev)
        })),
        ("loopy-bp", Box::new(|t| {
            LoopyBp::new(&net, LoopyBpOptions { threads: t, ..Default::default() }).query_all(&ev)
        })),
    ];

    for (name, run) in &engines {
        let mut results: Vec<Measurement> = Vec::new();
        for &t in &threads_sweep {
            results.push(bench(format!("{name} x{t}"), 1, 3, || run(t)));
        }
        report(
            &format!("{name} on alarm_like ({} vars, {} samples)", net.n_vars(), n_samples),
            &results,
        );
    }
}
