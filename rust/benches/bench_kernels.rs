//! Compiled message kernels bench — fused plans vs the classic three-op
//! path, written to `BENCH_kernels.json`:
//!
//! * **cold calibration latency** — a reused `JtEngine` alternating
//!   between two evidence sets (so every call really re-runs message
//!   passing), fused vs classic, sequential and hybrid schedules.
//! * **warm-start latency** — `CompiledTree::recalibrate_from` a base
//!   snapshot, fused vs classic (the serving warm path).
//! * **allocation counts** — a counting global allocator measures heap
//!   allocations per steady-state calibration; with `messages = 2(k-1)`
//!   per calibration this gives the per-message allocation count. The
//!   fused path is asserted to allocate **zero per message** (its only
//!   steady-state allocation is the per-calibration evidence signature
//!   clone), and the engine's arena counter is asserted not to move.
//! * **batched calibration** — B sequential fused calibrations vs one
//!   `calibrate_batch` stacked pass at B ∈ {4, 16, 64}, plus a
//!   SIMD-padding on/off ablation; the B=16 alarm_like row gates CI at
//!   ≥ 1.3× over fused-sequential.
//!
//! Fused and classic answers are cross-checked at 1e-12 before anything
//! is timed (batched lanes likewise against per-evidence fused).
//! `FASTPGM_BENCH_QUICK=1` shrinks sample counts for CI smoke runs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, bench, report};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::{
    CalibrationMode, CompiledTree, JunctionTree, KernelMode,
};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::repository;
use fastpgm::rng::Pcg;

/// Counts every heap allocation of the process — the ground truth behind
/// the "zero per-message allocations" claim (the arena counter is the
/// in-library view; this is the allocator's).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const WARMUP: usize = 3;
const BASE_OBS: usize = 3;
const DELTA_OBS: usize = 2;

fn main() {
    println!("== compiled message kernels: fused vs classic ==");
    let samples = benchkit::scaled(25, 4);
    let alloc_iters = benchkit::scaled(50, 5);
    let threads = fastpgm::parallel::default_threads().max(2);
    let mut scenarios: Vec<Json> = Vec::new();

    for (net_idx, name) in ["child_like", "alarm_like"].into_iter().enumerate() {
        let net = repository::by_name_extended(name).expect("known network");
        let jt = JunctionTree::build(&net);
        let n_cliques = jt.cliques.len();
        let messages_per_cal = 2 * (n_cliques - 1);
        println!(
            "\n-- {name}: {} vars, {n_cliques} cliques, {messages_per_cal} messages \
             per calibration --",
            net.n_vars()
        );

        // Evidence from one forward sample so P(e) > 0 for every subset;
        // two disjoint-prefix sets force real recalibrations when a
        // reused engine alternates between them.
        let mut rng = Pcg::seed_from(0xBEEF + net_idx as u64);
        let assignment = fastpgm::sampling::forward_sample(&net, &mut rng);
        let vars = rng.choose_k(net.n_vars(), 2 * BASE_OBS + DELTA_OBS);
        let ev_a: Evidence =
            vars[..BASE_OBS].iter().map(|&v| (v, assignment.get(v))).collect();
        let ev_b: Evidence = vars[BASE_OBS..2 * BASE_OBS]
            .iter()
            .map(|&v| (v, assignment.get(v)))
            .collect();
        let full: Evidence = vars[..BASE_OBS + DELTA_OBS]
            .iter()
            .map(|&v| (v, assignment.get(v)))
            .collect();

        // Correctness gate before timing anything: fused == classic.
        let mut dev: f64 = 0.0;
        for ev in [&ev_a, &ev_b, &full] {
            let mut fused = jt.engine();
            let mut classic = jt.engine();
            classic.kernel = KernelMode::Classic;
            for (f, c) in fused.query_all(ev).iter().zip(&classic.query_all(ev)) {
                for (a, b) in f.iter().zip(c) {
                    dev = dev.max((a - b).abs());
                }
            }
            assert!(
                (fused.evidence_probability() - classic.evidence_probability()).abs()
                    <= 1e-12,
                "{name}: P(e) diverges between kernels"
            );
        }
        assert!(dev <= 1e-12, "{name}: fused deviates from classic by {dev:.2e}");
        println!("  correctness: max |fused - classic| = {dev:.2e}");

        // Cold-calibration latency, engine reused, evidence alternating.
        for (mode, mode_threads, mode_label) in [
            (CalibrationMode::Sequential, 1usize, "sequential"),
            (CalibrationMode::Hybrid, threads, "hybrid"),
        ] {
            let mut rows = Vec::new();
            let mut medians = [0.0f64; 2];
            for (slot, kernel) in [KernelMode::Fused, KernelMode::Classic]
                .into_iter()
                .enumerate()
            {
                let mut eng = jt.parallel_engine(mode, mode_threads);
                eng.kernel = kernel;
                let mut flip = false;
                let m = bench(
                    format!("{name} cold {} {mode_label}", kernel.label()),
                    WARMUP,
                    samples,
                    || {
                        flip = !flip;
                        eng.calibrate(if flip { &ev_a } else { &ev_b });
                        eng.evidence_probability()
                    },
                );
                medians[slot] = m.median().as_secs_f64();
                rows.push(m);
            }
            report(&format!("{name} cold calibration ({mode_label})"), &rows);
            scenarios.push(Json::obj([
                ("net", Json::str(name)),
                ("mode", Json::str("cold")),
                ("schedule", Json::str(mode_label)),
                ("n_cliques", Json::num(n_cliques as f64)),
                ("fused_median_us", Json::num(medians[0] * 1e6)),
                ("classic_median_us", Json::num(medians[1] * 1e6)),
                ("fused_speedup", Json::num(medians[1] / medians[0].max(1e-12))),
            ]));
        }

        // Warm-start latency through the serving path.
        let fused_ct = CompiledTree::compile(&net);
        let classic_ct = CompiledTree::compile(&net).with_kernel(KernelMode::Classic);
        let base_f = fused_ct.calibrate(&ev_a);
        let base_c = classic_ct.calibrate(&ev_a);
        let warm_full: Evidence = {
            // Extend ev_a so the warm path has a real delta to absorb.
            let mut e = ev_a.clone();
            for &v in &vars[2 * BASE_OBS..] {
                e.set(v, assignment.get(v));
            }
            e
        };
        let wf = fused_ct.recalibrate_from(&base_f, &warm_full);
        let wc = classic_ct.recalibrate_from(&base_c, &warm_full);
        let mut wdev: f64 = 0.0;
        for (a, b) in wf.posterior_all().iter().zip(&wc.posterior_all()) {
            for (x, y) in a.iter().zip(b) {
                wdev = wdev.max((x - y).abs());
            }
        }
        assert!(wdev <= 1e-12, "{name}: warm fused deviates by {wdev:.2e}");
        let warm_fused = bench(format!("{name} warm fused"), WARMUP, samples, || {
            fused_ct.recalibrate_from(&base_f, &warm_full)
        });
        let warm_classic = bench(format!("{name} warm classic"), WARMUP, samples, || {
            classic_ct.recalibrate_from(&base_c, &warm_full)
        });
        report(
            &format!("{name} warm-start recalibration"),
            &[warm_fused.clone(), warm_classic.clone()],
        );
        scenarios.push(Json::obj([
            ("net", Json::str(name)),
            ("mode", Json::str("warm")),
            ("delta_obs", Json::num(DELTA_OBS as f64)),
            ("fused_median_us", Json::num(warm_fused.median().as_secs_f64() * 1e6)),
            (
                "classic_median_us",
                Json::num(warm_classic.median().as_secs_f64() * 1e6),
            ),
            (
                "fused_speedup",
                Json::num(
                    warm_classic.median().as_secs_f64()
                        / warm_fused.median().as_secs_f64().max(1e-12),
                ),
            ),
            ("max_abs_dev", Json::num(wdev)),
        ]));

        // Steady-state allocation counts (sequential, reused engine).
        let mut per_cal = [0.0f64; 2];
        for (slot, kernel) in
            [KernelMode::Fused, KernelMode::Classic].into_iter().enumerate()
        {
            let mut eng = jt.engine();
            eng.kernel = kernel;
            eng.calibrate(&ev_a);
            eng.calibrate(&ev_b); // buffers + arena now warm
            let arena_before = eng.arena_allocations();
            let a0 = ALLOCS.load(Ordering::Relaxed);
            for _ in 0..alloc_iters {
                eng.calibrate(&ev_a);
                eng.calibrate(&ev_b);
            }
            let delta = ALLOCS.load(Ordering::Relaxed) - a0;
            per_cal[slot] = delta as f64 / (2 * alloc_iters) as f64;
            if kernel == KernelMode::Fused {
                assert_eq!(
                    eng.arena_allocations(),
                    arena_before,
                    "{name}: arena grew during steady-state fused calibration"
                );
                // The only steady-state allocation is the per-calibration
                // evidence-signature clone — nothing per message.
                assert!(
                    per_cal[slot] < messages_per_cal as f64,
                    "{name}: fused path allocates per message ({} per cal, {} msgs)",
                    per_cal[slot],
                    messages_per_cal
                );
                assert!(
                    per_cal[slot] <= 2.0,
                    "{name}: unexpected steady-state fused allocations: {}",
                    per_cal[slot]
                );
            }
        }
        let per_msg =
            |cal: f64| (cal / messages_per_cal as f64 * 1000.0).round() / 1000.0;
        println!(
            "  allocations/calibration: fused {:.1} (= {:.3}/msg), classic {:.1} \
             (= {:.3}/msg)",
            per_cal[0],
            per_msg(per_cal[0]),
            per_cal[1],
            per_msg(per_cal[1])
        );
        scenarios.push(Json::obj([
            ("net", Json::str(name)),
            ("mode", Json::str("allocs")),
            ("messages_per_calibration", Json::num(messages_per_cal as f64)),
            ("fused_allocs_per_calibration", Json::num(per_cal[0])),
            ("classic_allocs_per_calibration", Json::num(per_cal[1])),
            ("fused_allocs_per_message", Json::num(per_msg(per_cal[0]))),
            ("classic_allocs_per_message", Json::num(per_msg(per_cal[1]))),
        ]));

        // Batched stacked-pass calibration: B sequential fused
        // calibrations vs ONE `calibrate_batch` pass over SoA-stacked
        // clique tables, plus the SIMD-padding on/off ablation at the
        // engine level. Bit-level parity is asserted before timing; the
        // B=16 alarm_like row carries the >= 1.3x CI gate.
        let batched_ct = CompiledTree::compile(&net).with_kernel(KernelMode::Batched);
        for batch in [4usize, 16, 64] {
            // Distinct positive-probability evidence sets, one per lane
            // (each drawn from its own forward sample).
            let evs: Vec<Evidence> = (0..batch)
                .map(|i| {
                    let mut r = Pcg::seed_from(0xB47C + (net_idx * 1000 + i) as u64);
                    let a = fastpgm::sampling::forward_sample(&net, &mut r);
                    r.choose_k(net.n_vars(), 2)
                        .into_iter()
                        .map(|v| (v, a.get(v)))
                        .collect()
                })
                .collect();

            // Parity gate before timing: every batched lane vs its
            // per-evidence fused calibration.
            let mut bdev: f64 = 0.0;
            for (lane, ev) in batched_ct.calibrate_batch(&evs).iter().zip(&evs) {
                let seq = fused_ct.calibrate(ev);
                bdev = bdev.max(
                    (lane.evidence_probability() - seq.evidence_probability()).abs(),
                );
                for (a, b) in lane.posterior_all().iter().zip(&seq.posterior_all()) {
                    for (x, y) in a.iter().zip(b) {
                        bdev = bdev.max((x - y).abs());
                    }
                }
            }
            assert!(
                bdev <= 1e-12,
                "{name} B={batch}: batched deviates from fused by {bdev:.2e}"
            );

            let seq = bench(
                format!("{name} fused x{batch} sequential"),
                WARMUP,
                samples,
                || {
                    let mut s = 0.0;
                    for ev in &evs {
                        s += fused_ct.calibrate(ev).evidence_probability();
                    }
                    s
                },
            );
            let one = bench(format!("{name} batched B={batch}"), WARMUP, samples, || {
                batched_ct
                    .calibrate_batch(&evs)
                    .iter()
                    .map(|l| l.evidence_probability())
                    .sum::<f64>()
            });
            report(
                &format!("{name} batched calibration (B={batch})"),
                &[seq.clone(), one.clone()],
            );
            let speedup =
                seq.median().as_secs_f64() / one.median().as_secs_f64().max(1e-12);
            if name == "alarm_like" && batch == 16 {
                assert!(
                    speedup >= 1.3,
                    "{name} B=16: batched speedup {speedup:.2}x below the 1.3x gate"
                );
            }

            // SIMD-padding ablation at the engine level (only B=4 is not
            // already a multiple of the register width).
            let mut pad_on = jt.engine();
            pad_on.kernel = KernelMode::Batched;
            let mut pad_off = jt.engine();
            pad_off.kernel = KernelMode::Batched;
            pad_off.batch_pad = false;
            let padded = bench(
                format!("{name} batched B={batch} padded"),
                WARMUP,
                samples,
                || {
                    pad_on
                        .calibrate_batch(&evs)
                        .iter()
                        .map(|l| l.evidence_prob)
                        .sum::<f64>()
                },
            );
            let unpadded = bench(
                format!("{name} batched B={batch} unpadded"),
                WARMUP,
                samples,
                || {
                    pad_off
                        .calibrate_batch(&evs)
                        .iter()
                        .map(|l| l.evidence_prob)
                        .sum::<f64>()
                },
            );
            scenarios.push(Json::obj([
                ("net", Json::str(name)),
                ("mode", Json::str("batched")),
                ("kernel", Json::str(KernelMode::Batched.as_str())),
                ("batch", Json::num(batch as f64)),
                ("fused_seq_median_us", Json::num(seq.median().as_secs_f64() * 1e6)),
                ("batched_median_us", Json::num(one.median().as_secs_f64() * 1e6)),
                ("batched_speedup", Json::num(speedup)),
                (
                    "padded_median_us",
                    Json::num(padded.median().as_secs_f64() * 1e6),
                ),
                (
                    "unpadded_median_us",
                    Json::num(unpadded.median().as_secs_f64() * 1e6),
                ),
                (
                    "padded_speedup_vs_unpadded",
                    Json::num(
                        unpadded.median().as_secs_f64()
                            / padded.median().as_secs_f64().max(1e-12),
                    ),
                ),
                ("max_abs_dev", Json::num(bdev)),
            ]));
        }
    }

    let out = Json::obj([
        ("bench", Json::str("kernels")),
        (
            "config",
            Json::obj([
                ("samples", Json::num(samples as f64)),
                ("alloc_iters", Json::num(alloc_iters as f64)),
                ("base_obs", Json::num(BASE_OBS as f64)),
                ("delta_obs", Json::num(DELTA_OBS as f64)),
                ("threads", Json::num(threads as f64)),
                ("quick", Json::num(if benchkit::quick() { 1.0 } else { 0.0 })),
            ]),
        ),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = Path::new("BENCH_kernels.json");
    benchkit::json::write(path, &out).expect("writing BENCH_kernels.json");
    println!("\nwrote {}", path.display());
}
