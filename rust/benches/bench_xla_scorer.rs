//! E9 (architecture) — batched AOT scorer throughput: the XLA/Pallas
//! artifact through PJRT vs the pure-Rust reference scorer vs per-query
//! junction-tree inference, across batch fills. Also measures the
//! coordinator's end-to-end overhead (batcher + channels) on top of raw
//! executor calls.
//!
//! Requires the `xla-runtime` feature *and* `make artifacts`; without the
//! feature this target compiles to a loud no-op so plain CI builds stay
//! green.

#[cfg(not(feature = "xla-runtime"))]
fn main() {
    println!("SKIP bench_xla_scorer: built without the xla-runtime feature");
}

#[cfg(feature = "xla-runtime")]
fn main() {
    xla_bench::run();
}

#[cfg(feature = "xla-runtime")]
mod xla_bench {
    use fastpgm::benchkit::{bench, report, throughput, Measurement};
    use fastpgm::coordinator::{BatcherConfig, Router};
    use fastpgm::core::Evidence;
    use fastpgm::inference::exact::JunctionTree;
    use fastpgm::inference::InferenceEngine;
    use fastpgm::rng::Pcg;
    use fastpgm::runtime::{ArtifactBundle, BatchScorer, ReferenceScorer, Scorer};
    use std::path::Path;
    use std::time::Duration;

    pub fn run() {
        println!("== E9: batched XLA scorer vs rust baselines ==");
        for name in ["asia", "child_like", "alarm_like"] {
            let Ok(bundle) = ArtifactBundle::locate(Path::new("artifacts"), name) else {
                println!("SKIP {name}: artifacts missing (run `make artifacts`)");
                continue;
            };
            let meta = bundle.read_meta().unwrap();
            let scorer = match BatchScorer::load(&bundle) {
                Ok(s) => s,
                Err(e) => {
                    println!("SKIP {name}: {e:#}");
                    continue;
                }
            };
            let net = scorer.net.clone();
            let reference = ReferenceScorer::new(net.clone(), meta.class_var, meta.batch);

            let mut rng = Pcg::seed_from(909);
            let rows: Vec<Vec<u8>> = (0..meta.batch)
                .map(|_| fastpgm::sampling::forward_sample(&net, &mut rng).values)
                .collect();

            let mut results: Vec<Measurement> = Vec::new();
            for fill in [meta.batch / 4, meta.batch] {
                let chunk = &rows[..fill];
                results.push(bench(
                    format!("{name} rust reference, {fill} rows"),
                    1,
                    5,
                    || reference.score(chunk).unwrap(),
                ));
                results.push(bench(
                    format!("{name} XLA artifact, {fill} rows"),
                    1,
                    5,
                    || scorer.score(chunk).unwrap(),
                ));
            }
            // Per-query junction tree (what a non-batched exact server does).
            let jt = JunctionTree::build(&net);
            let mut engine = jt.engine();
            let q_rows = &rows[..16.min(rows.len())];
            results.push(bench(
                format!("{name} per-query junction tree, 16 rows"),
                0,
                3,
                || {
                    q_rows
                        .iter()
                        .map(|row| {
                            let ev: Evidence = (0..net.n_vars())
                                .filter(|&v| v != meta.class_var)
                                .map(|v| (v, row[v] as usize))
                                .collect();
                            engine.query(meta.class_var, &ev)
                        })
                        .collect::<Vec<_>>()
                },
            ));
            report(
                &format!("{name} (batch={}, K={})", meta.batch, meta.n_classes),
                &results,
            );
            // Throughput summary for the full-batch XLA row.
            if let Some(m) = results.iter().find(|m| {
                m.label.contains("XLA") && m.label.contains(&format!("{} rows", meta.batch))
            }) {
                println!(
                    "  XLA full-batch throughput: {:.0} posteriors/s",
                    throughput(meta.batch, m.median())
                );
            }

            // Coordinator overhead: batched pipeline end-to-end.
            let mut router = Router::new();
            let b2 = bundle.clone();
            router
                .register_with(
                    name,
                    Box::new(move || Ok(Box::new(BatchScorer::load(&b2)?) as _)),
                    BatcherConfig::new()
                        .with_max_batch(meta.batch)
                        .with_max_wait(Duration::from_micros(500)),
                )
                .unwrap();
            let n_requests = rows.len();
            let m = bench(
                format!("{name} coordinator e2e, {n_requests} async requests"),
                1,
                3,
                || {
                    let rxs: Vec<_> = rows
                        .iter()
                        .map(|r| router.classify_async(name, r.clone()).unwrap())
                        .collect();
                    rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect::<Vec<_>>()
                },
            );
            println!(
                "  coordinator e2e: {} median for {n_requests} requests ({:.0} req/s)",
                fastpgm::benchkit::fmt_duration(m.median()),
                throughput(n_requests, m.median())
            );
        }
    }
}
