//! Observability overhead bench — the cost of the instrumentation itself,
//! written to `BENCH_obs.json`.
//!
//! Drives the cached hot path (one model, warmed calibration cache — the
//! configuration where per-query work is smallest and any fixed
//! per-query instrumentation cost is therefore *largest* in relative
//! terms) through a [`QueryRouter`] at each observability level:
//!
//! * `off`      — `ObsLevel::Off`: no stage clocks, no span assembly.
//! * `counters` — base counters/latency histogram only.
//! * `full`     — per-stage histograms + span assembly (the default).
//!
//! The acceptance gate: full-span instrumentation costs < 5% throughput
//! vs `off` on this hot path. The ratio is always emitted; the assert is
//! skipped under `FASTPGM_BENCH_QUICK=1` (CI smoke runs are too noisy
//! for a 5% latency comparison to be meaningful).

use fastpgm::benchkit::json::Json;
use fastpgm::benchkit::{self, report, Measurement};
use fastpgm::core::Evidence;
use fastpgm::network::{repository, BayesianNetwork};
use fastpgm::rng::Pcg;
use fastpgm::serving::{
    ObsConfig, ObsLevel, QueryEngineConfig, QueryRequest, QueryRouter,
};
use fastpgm::testkit;
use std::path::Path;
use std::time::{Duration, Instant};

const EVIDENCE_POOL: usize = 16;
const CACHE_CAPACITY: usize = 64;
const ROUNDS: usize = 3;

fn queries() -> usize {
    if benchkit::quick() {
        512
    } else {
        4096
    }
}

/// The request stream: pool-cycled evidence so the cache serves hits.
fn workload(net: &BayesianNetwork, n: usize) -> Vec<(Evidence, usize)> {
    let mut rng = Pcg::seed_from(0x0B5);
    let pool = testkit::gen_evidence_pool(&mut rng, net, EVIDENCE_POOL, 2);
    (0..n)
        .map(|i| {
            let ev = pool[i % pool.len()].clone();
            let var = testkit::gen_query_var(&mut rng, net, &ev);
            (ev, var)
        })
        .collect()
}

/// Time one pass of the stream through a router at the given level.
/// Returns per-query latencies (the warm-up pass that fills the cache is
/// untimed).
fn drive_level(
    net: &BayesianNetwork,
    stream: &[(Evidence, usize)],
    level: ObsLevel,
) -> Vec<Duration> {
    let mut router = QueryRouter::with_obs(2, ObsConfig::new().with_level(level));
    router.register(
        "asia",
        net,
        QueryEngineConfig::new().with_cache_capacity(CACHE_CAPACITY),
        Default::default(),
    );
    // Warm the calibration cache: one untimed query per pool entry.
    for (ev, var) in stream.iter().take(EVIDENCE_POOL) {
        router
            .query_routed("asia", QueryRequest::marginal(*var, ev.clone()))
            .expect("warm-up answers");
    }
    let mut lat = Vec::with_capacity(stream.len());
    for (ev, var) in stream {
        let t0 = Instant::now();
        router
            .query_routed("asia", QueryRequest::marginal(*var, ev.clone()))
            .expect("router answers");
        lat.push(t0.elapsed());
    }
    lat
}

fn main() {
    println!("== obs: instrumentation overhead on the cached hot path ==");
    let net = repository::asia();
    let stream = workload(&net, queries());
    let levels =
        [("off", ObsLevel::Off), ("counters", ObsLevel::Counters), ("full", ObsLevel::Full)];

    // Interleave rounds (off, counters, full, off, ...) so drift in the
    // machine's background load hits every level equally; keep the best
    // round per level (the least-perturbed measurement).
    let mut best: Vec<Option<Vec<Duration>>> = vec![None; levels.len()];
    for _ in 0..ROUNDS {
        for (i, (_, level)) in levels.iter().enumerate() {
            let lat = drive_level(&net, &stream, *level);
            let total: Duration = lat.iter().sum();
            let keep = match &best[i] {
                Some(prev) => total < prev.iter().sum::<Duration>(),
                None => true,
            };
            if keep {
                best[i] = Some(lat);
            }
        }
    }
    let best: Vec<Vec<Duration>> = best.into_iter().map(Option::unwrap).collect();

    let total_secs =
        |lat: &[Duration]| lat.iter().map(Duration::as_secs_f64).sum::<f64>();
    let off_total = total_secs(&best[0]);
    let rows: Vec<Measurement> = levels
        .iter()
        .zip(&best)
        .map(|((label, _), samples)| Measurement {
            label: format!("obs={label}"),
            samples: samples.clone(),
        })
        .collect();
    report(
        &format!("asia cached hot path ({} queries, pool={EVIDENCE_POOL})", queries()),
        &rows,
    );

    let mut scenarios: Vec<Json> = Vec::new();
    let mut full_ratio = 0.0;
    for ((label, _), lat) in levels.iter().zip(&best) {
        let total = total_secs(lat);
        let ratio = total / off_total.max(1e-12);
        if *label == "full" {
            full_ratio = ratio;
        }
        let m = Measurement { label: label.to_string(), samples: lat.clone() };
        println!(
            "  {label:>8}: {:>8.0} qps, p50 {:>6.1}us, overhead vs off {:+.1}%",
            lat.len() as f64 / total.max(1e-12),
            m.percentile(50.0).as_secs_f64() * 1e6,
            (ratio - 1.0) * 100.0
        );
        scenarios.push(Json::obj([
            ("level", Json::str(label)),
            ("queries", Json::num(lat.len() as f64)),
            ("throughput_qps", Json::num(lat.len() as f64 / total.max(1e-12))),
            ("p50_us", Json::num(m.percentile(50.0).as_secs_f64() * 1e6)),
            ("p99_us", Json::num(m.percentile(99.0).as_secs_f64() * 1e6)),
            ("overhead_vs_off", Json::num(ratio - 1.0)),
        ]));
    }

    let out = Json::obj([
        ("bench", Json::str("obs")),
        (
            "config",
            Json::obj([
                ("queries", Json::num(queries() as f64)),
                ("evidence_pool", Json::num(EVIDENCE_POOL as f64)),
                ("cache_capacity", Json::num(CACHE_CAPACITY as f64)),
                ("rounds", Json::num(ROUNDS as f64)),
                ("quick", Json::num(if benchkit::quick() { 1.0 } else { 0.0 })),
            ]),
        ),
        ("full_overhead_vs_off", Json::num(full_ratio - 1.0)),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = Path::new("BENCH_obs.json");
    benchkit::json::write(path, &out).expect("writing BENCH_obs.json");
    println!("\nwrote {}", path.display());

    if !benchkit::quick() {
        assert!(
            full_ratio < 1.05,
            "full-span instrumentation costs {:.1}% on the cached hot path \
             (gate: < 5% vs obs=off)",
            (full_ratio - 1.0) * 100.0
        );
    } else if full_ratio >= 1.05 {
        println!(
            "  NOTE: overhead {:.1}% above the 5% gate in quick mode (noisy; \
             the gate is enforced only on full runs)",
            (full_ratio - 1.0) * 100.0
        );
    }
}
