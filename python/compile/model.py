"""L2 JAX model: batched class-posterior scoring over a Bayesian network.

Builds, from a parsed `.fpgm` network, the jittable function

    classify(states: i32[B, N]) -> f32[B, K]

returning the **log joint** `log P(x_-c, class=k)` for every class value k
(the Rust runtime applies the softmax). The network's CPTs, parent lists
and strides are baked into the computation as constants, so the lowered
HLO is fully self-contained. The CPT gather hot spot is the L1 Pallas
kernel (`kernels.loglik`); everything around it (parent-config index
arithmetic, per-class vmap) is plain JAX that XLA fuses.
"""

from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from .fpgm import Network
from .kernels.loglik import batched_loglik
from .kernels.ref import compute_pcfg, loglik_ref

# Probability floor before taking logs: keeps every cpt_logs entry finite
# (deterministic CPTs contain exact zeros; -inf would poison the one-hot
# matmul with 0 * -inf = nan).
PROB_FLOOR = 1e-30


def pack_network(net: Network):
    """Pad the network into the dense tensors the kernel consumes.

    Returns (cpt_logs f32[N,P,C], parent_idx i32[N,Kmax],
    parent_stride i32[N,Kmax]).
    """
    n = net.n_vars
    max_card = max(net.cards)
    max_cfg = max(c.shape[0] for c in net.cpts)
    kmax = max((len(p) for p in net.parents), default=0)
    kmax = max(kmax, 1)  # keep a real axis even for parentless networks

    cpt_logs = np.zeros((n, max_cfg, max_card), dtype=np.float32)
    parent_idx = np.zeros((n, kmax), dtype=np.int32)
    parent_stride = np.zeros((n, kmax), dtype=np.int32)
    for v in range(n):
        table = np.log(np.maximum(net.cpts[v], PROB_FLOOR)).astype(np.float32)
        cfgs, card = table.shape
        cpt_logs[v, :cfgs, :card] = table
        for k, (p, s) in enumerate(zip(net.parents[v], net.parent_strides(v))):
            parent_idx[v, k] = p
            parent_stride[v, k] = s
    return jnp.asarray(cpt_logs), jnp.asarray(parent_idx), jnp.asarray(parent_stride)


def make_loglik_fn(net: Network, *, use_pallas: bool = True,
                   block_b: int = 128) -> Callable:
    """`loglik(states: i32[B, N]) -> f32[B]` for complete assignments."""
    cpt_logs, parent_idx, parent_stride = pack_network(net)

    def loglik(states):
        pcfg = compute_pcfg(states, parent_idx, parent_stride)
        if use_pallas:
            return batched_loglik(pcfg, states, cpt_logs, block_b=block_b)
        return loglik_ref(pcfg, states, cpt_logs)

    return loglik


def affected_nodes(net: Network, class_var: int) -> list:
    """Nodes whose family factor depends on the class value: the class
    variable itself plus its children."""
    aff = {class_var}
    for v in range(net.n_vars):
        if class_var in net.parents[v]:
            aff.add(v)
    return sorted(aff)


def pack_subnetwork(net: Network, nodes: list):
    """Pack only `nodes`' families (smaller P/C padding than the full
    network — the class family sub-tensor is usually tiny)."""
    max_card = max(net.cards[v] for v in nodes)
    max_cfg = max(net.cpts[v].shape[0] for v in nodes)
    kmax = max((len(net.parents[v]) for v in nodes), default=0)
    kmax = max(kmax, 1)
    a = len(nodes)
    cpt_logs = np.zeros((a, max_cfg, max_card), dtype=np.float32)
    parent_idx = np.zeros((a, kmax), dtype=np.int32)
    parent_stride = np.zeros((a, kmax), dtype=np.int32)
    for i, v in enumerate(nodes):
        table = np.log(np.maximum(net.cpts[v], PROB_FLOOR)).astype(np.float32)
        cfgs, card = table.shape
        cpt_logs[i, :cfgs, :card] = table
        for k, (p, s) in enumerate(zip(net.parents[v], net.parent_strides(v))):
            parent_idx[i, k] = p
            parent_stride[i, k] = s
    return jnp.asarray(cpt_logs), jnp.asarray(parent_idx), jnp.asarray(parent_stride)


def make_classify_fn(net: Network, class_var: int, *,
                     use_pallas: bool = True,
                     block_b: int = 128,
                     use_delta: bool = True) -> Callable:
    """`classify(states: i32[B, N]) -> f32[B, K]` — log joint per class.

    With `use_delta` (the optimized default), the class-invariant part of
    the joint is computed **once**: only the families of the class
    variable and its children depend on the class value, so

        score_k = base(class=0) - aff(class=0) + aff(class=k)

    where `aff` runs the kernel over the |affected| ≤ 1 + #children nodes
    only. Kernel node-work drops from K·N to N + K·A (the L2 "no
    redundant recomputation" target from DESIGN.md §Perf).
    """
    k_classes = net.cards[class_var]
    loglik = make_loglik_fn(net, use_pallas=use_pallas, block_b=block_b)
    if not use_delta:
        def classify_naive(states):
            def score_class(k):
                states_k = states.at[:, class_var].set(k)
                return loglik(states_k)                      # [B]
            scores = jax.vmap(score_class)(
                jnp.arange(k_classes, dtype=states.dtype))
            return (scores.T,)  # 1-tuple: matches the rust to_tuple1 unwrap
        return classify_naive

    aff = affected_nodes(net, class_var)
    aff_arr = jnp.asarray(np.array(aff, dtype=np.int32))
    cpt_aff, pidx_aff, pstride_aff = pack_subnetwork(net, aff)

    def loglik_aff(states):
        pcfg = compute_pcfg(states, pidx_aff, pstride_aff)   # [B, A]
        st_local = states[:, aff_arr]                        # [B, A]
        if use_pallas:
            return batched_loglik(pcfg, st_local, cpt_aff, block_b=block_b)
        return loglik_ref(pcfg, st_local, cpt_aff)

    def classify(states):
        s0 = states.at[:, class_var].set(0)
        base0 = loglik(s0)                                   # [B]
        def aff_class(k):
            return loglik_aff(states.at[:, class_var].set(k))
        affs = jax.vmap(aff_class)(
            jnp.arange(k_classes, dtype=states.dtype))       # [K, B]
        scores = base0[None, :] - affs[0][None, :] + affs    # [K, B]
        return (scores.T,)

    return classify
