"""AOT compile path: lower the L2 classify model to HLO text artifacts.

Run once by `make artifacts` (after the Rust `export` step wrote the
`.fpgm` + `_meta.txt` bundles). Never imported at runtime — the Rust
binary loads the HLO text through PJRT directly.

HLO **text** is the interchange format: jax >= 0.5 serializes
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. (See /opt/xla-example/README.md.)

Usage:
    python -m compile.aot --artifacts ../artifacts [--block 128]
"""

from __future__ import annotations

import argparse
import glob
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import fpgm
from .model import make_classify_fn


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides big constant tensors as `{...}`, which would silently strip
    # the baked CPTs from the artifact.
    text = comp.as_hlo_text(True)
    assert "..." not in text, "HLO printer elided a constant"
    return text


def compile_bundle(artifacts_dir: str, name: str, *, block_b: int) -> str:
    """Lower one network's classify model; returns the HLO path."""
    net = fpgm.load(os.path.join(artifacts_dir, f"{name}.fpgm"))
    with open(os.path.join(artifacts_dir, f"{name}_meta.txt")) as f:
        meta = fpgm.parse_meta(f.read())
    batch = int(meta["batch"])
    class_var = int(meta["class_var"])
    assert int(meta["n_vars"]) == net.n_vars, f"{name}: meta/fpgm mismatch"

    block = min(block_b, batch)
    while batch % block != 0:
        block //= 2
    classify = make_classify_fn(net, class_var, use_pallas=True, block_b=block)
    spec = jax.ShapeDtypeStruct((batch, net.n_vars), jnp.int32)
    lowered = jax.jit(classify).lower(spec)
    text = to_hlo_text(lowered)
    out_path = os.path.join(artifacts_dir, f"{name}_classify_b{batch}.hlo.txt")
    with open(out_path, "w") as f:
        f.write(text)
    print(f"  {name}: B={batch} N={net.n_vars} K={net.cards[class_var]} "
          f"block={block} -> {out_path} ({len(text)} chars)")
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts",
                    help="directory with .fpgm/_meta.txt bundles (from "
                         "`fastpgm export`)")
    ap.add_argument("--block", type=int, default=128,
                    help="pallas batch tile size")
    ap.add_argument("--out", default=None,
                    help="legacy single-output mode (unused; kept for "
                         "Makefile compatibility)")
    args = ap.parse_args()

    metas = sorted(glob.glob(os.path.join(args.artifacts, "*_meta.txt")))
    if not metas:
        print(f"no *_meta.txt bundles in {args.artifacts} — "
              f"run `cargo run --release -- export` first", file=sys.stderr)
        sys.exit(1)
    print(f"AOT-compiling {len(metas)} artifact(s):")
    for meta_path in metas:
        name = os.path.basename(meta_path)[: -len("_meta.txt")]
        compile_bundle(args.artifacts, name, block_b=args.block)


if __name__ == "__main__":
    main()
