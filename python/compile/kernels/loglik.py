"""L1 Pallas kernel: batched CPT gather-and-accumulate.

The hot spot of batched Bayesian-network scoring is, per sample and per
node, fetching `log P(state | parent-config)` from the network's CPTs and
summing. A scalar implementation is a pure gather — irregular, cache
hostile (exactly the access pattern Fast-PGM's optimizations (v) and (vii)
attack on CPUs). The TPU adaptation reorganizes the CPTs into one dense
padded tensor `cpt_logs[N, P, C]` and converts the gather into two
contractions that map onto the MXU:

    pconf_onehot[b, n, :]  @  cpt_logs[n, :, :]   ->  sel[b, n, :]
    sel[b, n, :]           ·  state_onehot[b, n, :]  (reduce)

The batch dimension is tiled by BlockSpec so each grid step holds one
batch tile plus the whole (small) CPT tensor in VMEM.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime executes. See DESIGN.md §Hardware-Adaptation for the VMEM / MXU
sizing estimates on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _loglik_kernel(pcfg_ref, states_ref, cpt_ref, out_ref):
    """One batch tile: out[b] = Σ_n cpt[n, pcfg[b,n], states[b,n]]."""
    pc = pcfg_ref[...]          # i32[bb, N]
    st = states_ref[...]        # i32[bb, N]
    cl = cpt_ref[...]           # f32[N, P, C]
    n_p = cl.shape[1]
    n_c = cl.shape[2]
    # One-hot over parent configurations; the contraction with cpt_logs is
    # a batched (per-node) matmul -> MXU.
    onehot_p = (pc[:, :, None] == jnp.arange(n_p, dtype=pc.dtype)[None, None, :])
    onehot_p = onehot_p.astype(cl.dtype)                     # [bb, N, P]
    sel = jnp.einsum("bnp,npc->bnc", onehot_p, cl)           # [bb, N, C]
    onehot_c = (st[:, :, None] == jnp.arange(n_c, dtype=st.dtype)[None, None, :])
    onehot_c = onehot_c.astype(cl.dtype)                     # [bb, N, C]
    out_ref[...] = jnp.sum(sel * onehot_c, axis=(1, 2))      # [bb]


@functools.partial(jax.jit, static_argnames=("block_b",))
def batched_loglik(pcfg, states, cpt_logs, *, block_b: int = 128):
    """Batched log-likelihood via the Pallas kernel.

    Args:
      pcfg:     i32[B, N] parent-configuration index per (sample, node).
      states:   i32[B, N] state index per (sample, node).
      cpt_logs: f32[N, P, C] log-CPTs, padded; entries must be finite
                (clamp zeros before taking logs — `-inf * 0 = nan` would
                poison the one-hot contraction).
      block_b:  batch tile size (must divide B).

    Returns:
      f32[B] log joint probabilities.
    """
    b, n = pcfg.shape
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block {block_b}")
    _, p, c = cpt_logs.shape
    grid = (b // block_b,)
    return pl.pallas_call(
        _loglik_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((n, p, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), cpt_logs.dtype),
        interpret=True,
    )(pcfg, states, cpt_logs)


def vmem_estimate_bytes(n: int, p: int, c: int, block_b: int = 128,
                        dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (for DESIGN.md §Perf):
    batch tile inputs + CPT tensor + both one-hot intermediates + output."""
    tile_inputs = 2 * block_b * n * 4           # pcfg + states (i32)
    cpt = n * p * c * dtype_bytes
    onehots = block_b * n * (p + 2 * c) * dtype_bytes  # onehot_p, sel, onehot_c
    out = block_b * dtype_bytes
    return tile_inputs + cpt + onehots + out
