"""Pure-jnp oracle for the L1 kernel: the same batched log-likelihood
computed with `take_along_axis` gathers instead of one-hot contractions.
Every kernel test asserts `batched_loglik == loglik_ref` to tight
tolerance; the AOT model can also be compiled against this path
(`use_pallas=False`) as an ablation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def loglik_ref(pcfg, states, cpt_logs):
    """f32[B] log joints from i32[B,N] pcfg/states and f32[N,P,C] CPTs."""
    # per_node[b, n] = cpt_logs[n, pcfg[b, n], states[b, n]]
    n = cpt_logs.shape[0]
    node_idx = jnp.arange(n)[None, :]                       # [1, N]
    per_node = cpt_logs[node_idx, pcfg, states]             # [B, N]
    return jnp.sum(per_node, axis=1)


def compute_pcfg(states, parent_idx, parent_stride):
    """i32[B, N] parent-configuration indices.

    `parent_idx`/`parent_stride` are i32[N, Kmax], zero-padded; padded
    entries contribute 0 because their stride is 0.
    """
    gathered = states[:, parent_idx]                        # [B, N, Kmax]
    return jnp.sum(gathered * parent_stride[None, :, :], axis=2).astype(jnp.int32)
