"""Parser for the native `.fpgm` network format.

Mirrors `rust/src/io/fpgm.rs` — the Rust `export` subcommand writes these
files, and the AOT compile path reads them so both layers operate on the
bit-identical network. See DESIGN.md §Artifact flow.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class Network:
    """A discrete Bayesian network in canonical (sorted-parent) layout."""

    name: str
    var_names: List[str]
    cards: List[int]                 # cardinality per variable
    parents: List[List[int]]         # sorted parent ids per variable
    cpts: List[np.ndarray]           # [n_parent_configs, card] per variable

    @property
    def n_vars(self) -> int:
        return len(self.cards)

    def parent_strides(self, v: int) -> List[int]:
        """Mixed-radix strides (last parent fastest), matching
        `Cpt::parent_config_from` on the Rust side."""
        ps = self.parents[v]
        strides = [1] * len(ps)
        for i in range(len(ps) - 2, -1, -1):
            strides[i] = strides[i + 1] * self.cards[ps[i + 1]]
        return strides

    def log_joint(self, states: np.ndarray) -> float:
        """Reference log joint probability of one complete assignment
        (float64 — the test oracle)."""
        total = 0.0
        for v in range(self.n_vars):
            cfg = 0
            for p, s in zip(self.parents[v], self.parent_strides(v)):
                cfg += int(states[p]) * s
            prob = self.cpts[v][cfg, int(states[v])]
            total += np.log(max(prob, 1e-300))
        return total


def parse(text: str) -> Network:
    """Parse `.fpgm` text."""
    lines = [
        ln.strip()
        for ln in text.splitlines()
        if ln.strip() and not ln.strip().startswith("#")
    ]
    if not lines or lines[0] != "fpgm 1":
        raise ValueError(f"unsupported fpgm header: {lines[:1]}")
    name = "unnamed"
    var_names: List[str] = []
    cards: List[int] = []
    parents: List[List[int]] = []
    raw_cpts: List[np.ndarray] = []
    saw_end = False
    for ln in lines[1:]:
        tok = ln.split()
        if tok[0] == "name":
            name = " ".join(tok[1:])
        elif tok[0] == "var":
            var_names.append(tok[1])
            cards.append(int(tok[2]))
            parents.append([])
            raw_cpts.append(None)  # type: ignore[arg-type]
        elif tok[0] == "parents":
            v = int(tok[1])
            ps = sorted(int(t) for t in tok[2:])
            parents[v] = ps
        elif tok[0] == "cpt":
            v = int(tok[1])
            raw_cpts[v] = np.array([float(t) for t in tok[2:]], dtype=np.float64)
        elif tok[0] == "end":
            saw_end = True
            break
        else:
            raise ValueError(f"unknown fpgm directive: {tok[0]!r}")
    if not saw_end:
        raise ValueError("fpgm file missing 'end'")
    cpts = []
    for v in range(len(cards)):
        n_cfg = int(np.prod([cards[p] for p in parents[v]])) if parents[v] else 1
        table = raw_cpts[v]
        if table is None or table.size != n_cfg * cards[v]:
            raise ValueError(f"bad cpt for variable {v}")
        cpts.append(table.reshape(n_cfg, cards[v]))
    return Network(name, var_names, cards, parents, cpts)


def load(path: str) -> Network:
    with open(path) as f:
        return parse(f.read())


def parse_meta(text: str) -> dict:
    """Parse a `_meta.txt` sidecar into a dict of ints/strings."""
    out: dict = {}
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        k, v = ln.split(None, 1)
        out[k] = int(v) if v.strip().isdigit() else v.strip()
    return out
