"""L2 model correctness: packed network + classify graph against the
float64 reference `Network.log_joint`, on the real exported artifacts when
present and on a hand-built network otherwise."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import fpgm
from compile.model import make_classify_fn, make_loglik_fn, pack_network

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def hand_network() -> fpgm.Network:
    """sprinkler: cloudy -> {sprinkler, rain} -> wet."""
    return fpgm.Network(
        name="sprinkler",
        var_names=["cloudy", "sprinkler", "rain", "wet"],
        cards=[2, 2, 2, 2],
        parents=[[], [0], [0], [1, 2]],
        cpts=[
            np.array([[0.5, 0.5]]),
            np.array([[0.5, 0.5], [0.9, 0.1]]),
            np.array([[0.8, 0.2], [0.2, 0.8]]),
            np.array([[1.0, 0.0], [0.1, 0.9], [0.1, 0.9], [0.01, 0.99]]),
        ],
    )


def random_states(net, b, seed=0):
    rng = np.random.default_rng(seed)
    cols = [rng.integers(0, c, size=b) for c in net.cards]
    return np.stack(cols, axis=1).astype(np.int32)


def test_pack_shapes():
    net = hand_network()
    cpt_logs, pidx, pstride = pack_network(net)
    assert cpt_logs.shape == (4, 4, 2)
    assert pidx.shape == (4, 2)
    assert pstride.shape == (4, 2)
    # wet's parents (1, 2): strides (2, 1)
    assert list(np.asarray(pidx)[3]) == [1, 2]
    assert list(np.asarray(pstride)[3]) == [2, 1]


@pytest.mark.parametrize("use_pallas", [True, False])
def test_loglik_matches_reference(use_pallas):
    net = hand_network()
    states = random_states(net, 64, seed=3)
    fn = make_loglik_fn(net, use_pallas=use_pallas, block_b=32)
    got = np.asarray(fn(jnp.asarray(states)))
    want = np.array([net.log_joint(s) for s in states])
    # float32 kernel vs float64 oracle; deterministic zeros floored.
    finite = want > np.log(1e-29)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-4, atol=1e-4)


def test_classify_posterior_matches_enumeration():
    net = hand_network()
    class_var = 2  # rain
    states = random_states(net, 32, seed=5)
    classify = make_classify_fn(net, class_var, use_pallas=True, block_b=32)
    (scores,) = classify(jnp.asarray(states))
    scores = np.asarray(scores)  # [B, 2] log joints
    for b in range(8):
        # softmax(scores) must equal P(rain | all other vars).
        joints = []
        for k in range(2):
            s = states[b].copy()
            s[class_var] = k
            joints.append(np.exp(net.log_joint(s)))
        want = np.array(joints) / sum(joints)
        got = np.exp(scores[b] - scores[b].max())
        got = got / got.sum()
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_pallas_and_ref_models_agree():
    net = hand_network()
    states = jnp.asarray(random_states(net, 64, seed=7))
    f1 = make_classify_fn(net, 3, use_pallas=True, block_b=32)
    f2 = make_classify_fn(net, 3, use_pallas=False)
    (a,) = f1(states)
    (b,) = f2(states)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "asia.fpgm")),
    reason="artifacts not exported (run `make artifacts`)",
)
def test_exported_asia_matches_reference():
    net = fpgm.load(os.path.join(ARTIFACTS, "asia.fpgm"))
    with open(os.path.join(ARTIFACTS, "asia_meta.txt")) as f:
        meta = fpgm.parse_meta(f.read())
    states = random_states(net, 128, seed=11)
    fn = make_loglik_fn(net, use_pallas=True, block_b=64)
    got = np.asarray(fn(jnp.asarray(states)))
    want = np.array([net.log_joint(s) for s in states])
    finite = want > np.log(1e-29)
    assert finite.sum() > 0
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-4, atol=1e-3)
    assert meta["class_var"] == 4  # bronc


def test_fpgm_parser_rejects_bad_input():
    with pytest.raises(ValueError):
        fpgm.parse("not a network")
    with pytest.raises(ValueError):
        fpgm.parse("fpgm 1\nvar x 2\n")  # no cpt, no end


def test_delta_classify_equals_naive():
    """P3 optimization: delta scoring must be numerically identical to
    recomputing the full joint per class."""
    net = hand_network()
    states = jnp.asarray(random_states(net, 64, seed=13))
    for class_var in range(4):
        fd = make_classify_fn(net, class_var, use_pallas=True, block_b=32,
                              use_delta=True)
        fn = make_classify_fn(net, class_var, use_pallas=True, block_b=32,
                              use_delta=False)
        (a,) = fd(states)
        (b,) = fn(states)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
