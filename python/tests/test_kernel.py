"""L1 kernel correctness: Pallas `batched_loglik` vs the pure-jnp oracle,
including a hypothesis sweep over shapes and contents."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.loglik import batched_loglik, vmem_estimate_bytes
from compile.kernels.ref import compute_pcfg, loglik_ref


def random_case(rng, b, n, p, c):
    """Random padded inputs with the invariants the model guarantees:
    pcfg < P, states < C, finite cpt_logs."""
    pcfg = rng.integers(0, p, size=(b, n)).astype(np.int32)
    states = rng.integers(0, c, size=(b, n)).astype(np.int32)
    cpt_logs = np.log(
        rng.uniform(1e-6, 1.0, size=(n, p, c))
    ).astype(np.float32)
    return jnp.asarray(pcfg), jnp.asarray(states), jnp.asarray(cpt_logs)


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    pcfg, states, cpt_logs = random_case(rng, 128, 8, 4, 3)
    got = batched_loglik(pcfg, states, cpt_logs, block_b=64)
    want = loglik_ref(pcfg, states, cpt_logs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_single_block():
    rng = np.random.default_rng(1)
    pcfg, states, cpt_logs = random_case(rng, 32, 5, 2, 2)
    got = batched_loglik(pcfg, states, cpt_logs, block_b=32)
    want = loglik_ref(pcfg, states, cpt_logs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rejects_indivisible_block():
    rng = np.random.default_rng(2)
    pcfg, states, cpt_logs = random_case(rng, 100, 4, 2, 2)
    with pytest.raises(ValueError):
        batched_loglik(pcfg, states, cpt_logs, block_b=64)


def test_handles_floored_zero_probs():
    # Deterministic CPT entries are floored, not -inf; result stays finite.
    pcfg = jnp.zeros((16, 2), dtype=jnp.int32)
    states = jnp.zeros((16, 2), dtype=jnp.int32)
    cpt_logs = jnp.full((2, 1, 2), np.log(1e-30), dtype=jnp.float32)
    out = batched_loglik(pcfg, states, cpt_logs, block_b=16)
    assert np.all(np.isfinite(np.asarray(out)))


@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(1, 3),
    block=st.sampled_from([8, 16, 32]),
    n=st.integers(1, 12),
    p=st.integers(1, 9),
    c=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(b_blocks, block, n, p, c, seed):
    """Kernel == oracle across shapes, block sizes and contents."""
    rng = np.random.default_rng(seed)
    b = b_blocks * block
    pcfg, states, cpt_logs = random_case(rng, b, n, p, c)
    got = batched_loglik(pcfg, states, cpt_logs, block_b=block)
    want = loglik_ref(pcfg, states, cpt_logs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_pcfg_matches_manual(seed):
    """compute_pcfg against an explicit python loop."""
    rng = np.random.default_rng(seed)
    b, n, kmax = 7, 5, 3
    cards = rng.integers(2, 4, size=n)
    states = np.stack([rng.integers(0, cards[v], size=b) for v in range(n)], axis=1)
    parent_idx = rng.integers(0, n, size=(n, kmax)).astype(np.int32)
    # zero out some strides (padding)
    parent_stride = rng.integers(0, 3, size=(n, kmax)).astype(np.int32)
    got = np.asarray(
        compute_pcfg(jnp.asarray(states.astype(np.int32)),
                     jnp.asarray(parent_idx), jnp.asarray(parent_stride))
    )
    for bi in range(b):
        for v in range(n):
            expect = sum(
                int(states[bi, parent_idx[v, k]]) * int(parent_stride[v, k])
                for k in range(kmax)
            )
            assert got[bi, v] == expect


def test_vmem_estimate_within_budget():
    """The shipped artifact shapes fit a 16 MiB VMEM budget (DESIGN §Perf)."""
    # alarm_like worst case: N=37, P<=256, C=4.
    est = vmem_estimate_bytes(37, 256, 4, block_b=128)
    assert est < 16 * 1024 * 1024, f"VMEM estimate {est} too large"
