//! Side-by-side comparison of every inference engine in the library on
//! the same query — the "choose the right algorithm" demo the paper's
//! usability story is about.
//!
//! Run: `cargo run --release --example approx_vs_exact`

use fastpgm::core::Evidence;
use fastpgm::inference::approx::{
    AisBn, ApproxOptions, EpisBn, LikelihoodWeighting, LogicSampling, LoopyBp,
    LoopyBpOptions, SelfImportance,
};
use fastpgm::inference::exact::{JunctionTree, VariableElimination};
use fastpgm::inference::InferenceEngine;
use fastpgm::metrics::mean_hellinger;
use fastpgm::network::repository;

fn main() {
    let net = repository::asia();
    // Unlikely evidence — the regime where the samplers differentiate.
    let ev = Evidence::new()
        .with(net.var_index("tub").unwrap(), 1)
        .with(net.var_index("dysp").unwrap(), 1);
    println!("network = asia, evidence = tub:yes, dysp:yes (rare: P ≈ 0.005)\n");

    // Ground truth from the junction tree.
    let jt = JunctionTree::build(&net);
    let truth = jt.engine().query_all(&ev);

    let opts = ApproxOptions { n_samples: 40_000, ..Default::default() };
    let mut rows: Vec<(String, Vec<Vec<f64>>, std::time::Duration)> = Vec::new();
    macro_rules! run {
        ($engine:expr) => {{
            let mut e = $engine;
            let t0 = std::time::Instant::now();
            let posts = e.query_all(&ev);
            rows.push((e.name().to_string(), posts, t0.elapsed()));
        }};
    }
    run!(jt.engine());
    run!(VariableElimination::new(&net));
    run!(LoopyBp::new(&net, LoopyBpOptions::default()));
    run!(LogicSampling::new(&net, opts.clone()));
    run!(LikelihoodWeighting::new(&net, opts.clone()));
    run!(SelfImportance::new(&net, opts.clone()));
    run!(AisBn::new(&net, opts.clone()));
    run!(EpisBn::new(&net, opts.clone()));

    println!(
        "{:<22} {:>14} {:>10}   P(lung | e)",
        "engine", "mean Hellinger", "time"
    );
    let lung = net.var_index("lung").unwrap();
    for (name, posts, time) in &rows {
        let h = mean_hellinger(posts, &truth);
        println!(
            "{:<22} {:>14.5} {:>9.1?}   {:.4}",
            name,
            h,
            time,
            posts[lung][1]
        );
    }

    // The importance samplers must beat plain rejection on rare evidence.
    let h_of = |n: &str| {
        rows.iter()
            .find(|(name, ..)| name == n)
            .map(|(_, p, _)| mean_hellinger(p, &truth))
            .unwrap()
    };
    assert!(h_of("likelihood-weighting") < h_of("logic-sampling") + 1e-9);
    println!("\napprox_vs_exact OK");
}
