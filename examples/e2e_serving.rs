//! END-TO-END DRIVER: the serving stack on real workloads.
//!
//! Two serving paths run here:
//!
//! 1. **Posterior-query serving (pure Rust, always available)** — a
//!    [`QueryRouter`] over compiled junction trees with an LRU calibration
//!    cache, hammered by concurrent clients whose evidence repeats (the
//!    shape of production traffic). Every sampled response is cross-checked
//!    against a freshly built junction tree at 1e-12.
//! 2. **Classification serving (requires `--features xla-runtime` + `make
//!    artifacts`)** — the original three-layer path: L1 Pallas kernel in
//!    the L2 JAX classify graph, AOT-lowered and executed through PJRT by
//!    the L3 coordinator, cross-checked against the pure-Rust scorer and
//!    exact inference.
//!
//! Run: `cargo run --release --example e2e_serving [-- --requests 4096 --clients 8]`

use fastpgm::cli::Args;
use fastpgm::coordinator::{BatcherConfig, QueryRequest, QueryRouter};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::{JunctionTree, QueryEngineConfig};
use fastpgm::inference::InferenceEngine;
use fastpgm::network::repository;
use fastpgm::rng::Pcg;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    query_serving_demo(&args)?;
    approx_serving_demo(&args)?;

    #[cfg(feature = "xla-runtime")]
    xla_demo::run(&args)?;
    #[cfg(not(feature = "xla-runtime"))]
    eprintln!(
        "\n(xla classify section skipped: rebuild with --features xla-runtime \
         and run `make artifacts` to exercise the PJRT path)"
    );

    println!("\ne2e_serving OK");
    Ok(())
}

/// Concurrent posterior-query serving over the query router, with repeated
/// evidence (cache-friendly traffic) and exact cross-checks.
fn query_serving_demo(args: &Args) -> anyhow::Result<()> {
    let requests = args.parse_flag("requests", 4096usize);
    let clients = args.parse_flag("clients", 8usize).max(1);
    let pool_size = args.parse_flag("evidence-pool", 24usize).max(1);

    println!("=== posterior-query serving (compiled trees + calibration cache) ===");
    let mut router = QueryRouter::new(fastpgm::parallel::default_threads());
    let mut models = Vec::new();
    for name in ["asia", "child_like", "alarm_like"] {
        let net = repository::by_name_extended(name)
            .ok_or_else(|| anyhow::anyhow!("unknown network {name}"))?;
        router.register(
            name,
            &net,
            QueryEngineConfig::new().with_cache_capacity(128),
            BatcherConfig::default(),
        );
        models.push((name.to_string(), net));
    }

    // Bounded per-model evidence pools: serving traffic repeats itself.
    let mut rng = Pcg::seed_from(42);
    let pools: Vec<Vec<Evidence>> = models
        .iter()
        .map(|(_, net)| fastpgm::testkit::gen_evidence_pool(&mut rng, net, pool_size, 2))
        .collect();

    let router = Arc::new(router);
    let models = Arc::new(models);
    let pools = Arc::new(pools);
    let per_client = requests / clients;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let router = Arc::clone(&router);
            let models = Arc::clone(&models);
            let pools = Arc::clone(&pools);
            std::thread::spawn(move || -> anyhow::Result<Vec<(usize, Evidence, usize, Vec<f64>)>> {
                let mut rng = Pcg::seed_from(7_000 + c as u64);
                let mut sampled = Vec::new();
                for i in 0..per_client {
                    let m = (c + i) % models.len();
                    let (name, net) = &models[m];
                    let ev = pools[m][rng.below(pools[m].len())].clone();
                    let var = fastpgm::testkit::gen_query_var(&mut rng, net, &ev);
                    let p = router
                        .query(name, QueryRequest::marginal(var, ev.clone()))?
                        .into_marginal()
                        .ok_or_else(|| anyhow::anyhow!("wrong reply variant"))?;
                    // Keep a sparse sample for the exactness cross-check.
                    if i % 97 == 0 {
                        sampled.push((m, ev, var, p));
                    }
                }
                Ok(sampled)
            })
        })
        .collect();
    let mut sampled = Vec::new();
    for h in handles {
        sampled.extend(h.join().expect("client thread panicked")?);
    }
    let elapsed = t0.elapsed();
    let served = per_client * clients;
    println!(
        "served {served} posterior queries from {clients} clients in {elapsed:.2?} \
         -> {:.0} queries/s end-to-end",
        served as f64 / elapsed.as_secs_f64()
    );
    for (model, stats) in router.stats() {
        println!(
            "  {model}: {} | cache hit_rate={:.3} (hits={} warm_starts={} \
             cold_misses={} evictions={})",
            stats.serving.summary(),
            stats.cache.hit_rate(),
            stats.cache.hits,
            stats.cache.warm_starts,
            stats.cache.cold_misses,
            stats.cache.evictions
        );
    }

    // Cross-check: served posteriors == freshly built junction tree, to
    // within 1e-12 (the cache must be bit-compatible with cold inference).
    let mut max_dev: f64 = 0.0;
    let fresh: Vec<_> = models
        .iter()
        .map(|(_, net)| JunctionTree::build(net))
        .collect();
    let mut engines: Vec<_> = fresh.iter().map(|jt| jt.engine()).collect();
    for (m, ev, var, p) in &sampled {
        let expect = engines[*m].query(*var, ev);
        for (x, y) in p.iter().zip(&expect) {
            max_dev = max_dev.max((x - y).abs());
        }
    }
    println!(
        "  max |served - fresh junction tree| over {} sampled posteriors: {max_dev:.2e}",
        sampled.len()
    );
    anyhow::ensure!(max_dev <= 1e-12, "cached serving deviates from cold inference");
    Ok(())
}

/// The approximate serving tier under induced queue pressure: an
/// auto-routed model sheds batch-priority queries to chunked likelihood
/// weighting over the shared pool, interactive queries stay exact, and
/// every shed answer is cross-checked loosely against the exact engine.
fn approx_serving_demo(args: &Args) -> anyhow::Result<()> {
    use fastpgm::coordinator::{AnswerTier, ApproxConfig};
    use fastpgm::inference::approx::ApproxOptions;
    use fastpgm::inference::engine::EngineChoice;
    use fastpgm::inference::exact::QueryEngine;
    use std::time::Duration;

    let requests = args.parse_flag("approx-requests", 384usize).max(32);
    println!("\n=== approximate serving tier (auto shed under pressure) ===");
    let net = repository::asia();
    let mut router = QueryRouter::new(fastpgm::parallel::default_threads());
    router.register_with_approx(
        "asia",
        &net,
        QueryEngineConfig::new().with_cache_capacity(64),
        BatcherConfig::new()
            .with_max_batch(64)
            .with_max_wait(Duration::from_millis(20)),
        ApproxConfig::new()
            .with_engine(EngineChoice::Auto)
            .with_opts(ApproxOptions { n_samples: 20_000, ..Default::default() })
            .with_error_budget(0.01)
            .with_shed_queue_depth(2),
    );

    // Bounded evidence pool, restricted to evidence with non-negligible
    // probability so the loose accuracy cross-check below is meaningful.
    let exact = QueryEngine::new(&net);
    let mut rng = Pcg::seed_from(9);
    let mut pool = fastpgm::testkit::gen_evidence_pool(&mut rng, &net, 12, 2);
    pool.retain(|ev| exact.evidence_probability(ev) > 1e-3);
    anyhow::ensure!(!pool.is_empty(), "evidence pool filtered to nothing");

    // Bursts of async queries induce queue depth; every other query is
    // batch priority (sheddable), the rest interactive.
    let mut exact_served = 0usize;
    let mut approx_served = 0usize;
    let mut max_l1: f64 = 0.0;
    let waves = requests / 32;
    for wave in 0..waves {
        let receivers: Vec<_> = (0..32)
            .map(|i| {
                let ev = pool[(wave + i) % pool.len()].clone();
                let var = fastpgm::testkit::gen_query_var(&mut rng, &net, &ev);
                let mut request = QueryRequest::marginal(var, ev.clone());
                let batch = i % 2 == 0;
                if batch {
                    request = request.batch_priority();
                }
                (var, ev, batch, router.query_async("asia", request).unwrap())
            })
            .collect();
        for (var, ev, batch, rx) in receivers {
            let routed = rx.recv()?;
            if !batch {
                anyhow::ensure!(
                    routed.tier == AnswerTier::Exact,
                    "interactive query answered on the approx tier"
                );
            }
            match routed.tier {
                AnswerTier::Exact => exact_served += 1,
                AnswerTier::Approx => approx_served += 1,
            }
            let p = routed
                .into_marginal()
                .ok_or_else(|| anyhow::anyhow!("wrong reply variant"))?;
            let expect = exact.posterior(var, &ev);
            let l1: f64 = p.iter().zip(&expect).map(|(a, b)| (a - b).abs()).sum();
            max_l1 = max_l1.max(l1);
        }
    }
    let served = waves * 32;
    println!(
        "served {served} queries: exact tier={exact_served}, approx tier={approx_served} \
         (batch-priority under backlog sheds to chunked likelihood weighting)"
    );
    for (model, stats) in router.stats() {
        println!("  {model}: {}", stats.serving.summary());
    }
    println!("  max L1(served, exact) over every answer: {max_l1:.4}");
    anyhow::ensure!(approx_served > 0, "no query was shed to the approximate tier");
    anyhow::ensure!(max_l1 < 0.1, "approximate tier drifted from exact: L1 {max_l1}");
    Ok(())
}

/// The original XLA classify path, gated on the `xla-runtime` feature.
#[cfg(feature = "xla-runtime")]
mod xla_demo {
    use fastpgm::cli::Args;
    use fastpgm::classify::argmax;
    use fastpgm::coordinator::{BatcherConfig, Router};
    use fastpgm::core::Evidence;
    use fastpgm::inference::exact::JunctionTree;
    use fastpgm::inference::InferenceEngine;
    use fastpgm::io::fpgm;
    use fastpgm::rng::Pcg;
    use fastpgm::runtime::{ArtifactBundle, BatchScorer, ReferenceScorer, Scorer};
    use std::path::Path;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    pub fn run(args: &Args) -> anyhow::Result<()> {
        let requests = args.parse_flag("requests", 4096usize);
        let clients = args.parse_flag("clients", 8usize).max(1);
        let artifacts = Path::new("artifacts");

        let mut report = String::new();
        for name in ["asia", "child_like", "alarm_like"] {
            let bundle = match ArtifactBundle::locate(artifacts, name) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("skipping {name}: {e} (run `make artifacts`)");
                    continue;
                }
            };
            let meta = bundle.read_meta()?;
            let net = fpgm::load(&bundle.fpgm)?;
            println!(
                "\n=== {name}: {} vars, class={} ({} states), batch={} ===",
                meta.n_vars,
                net.variable(meta.class_var).name,
                meta.n_classes,
                meta.batch
            );

            // -- L3 coordinator over the L1/L2 XLA artifact ------------------
            let mut router = Router::new();
            let b2 = bundle.clone();
            router.register_with(
                name,
                Box::new(move || Ok(Box::new(BatchScorer::load(&b2)?) as _)),
                BatcherConfig::new()
                    .with_max_batch(meta.batch)
                    .with_max_wait(Duration::from_millis(1)),
            )?;

            // -- concurrent request stream ----------------------------------
            let router = Arc::new(router);
            let net_arc = Arc::new(net.clone());
            let t0 = Instant::now();
            let per_client = requests / clients;
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let router = Arc::clone(&router);
                    let net = Arc::clone(&net_arc);
                    let name = name.to_string();
                    std::thread::spawn(move || {
                        let mut rng = Pcg::seed_from(1000 + c as u64);
                        let mut correct = 0usize;
                        let mut posts = Vec::with_capacity(per_client);
                        for _ in 0..per_client {
                            let a = fastpgm::sampling::forward_sample(&net, &mut rng);
                            let post = router.classify(&name, a.values.clone()).unwrap();
                            if argmax(&post) == a.get(class_var_of(&net)) {
                                correct += 1;
                            }
                            posts.push((a, post));
                        }
                        (correct, posts)
                    })
                })
                .collect();
            let mut correct = 0usize;
            let mut all: Vec<(fastpgm::core::Assignment, Vec<f64>)> = Vec::new();
            for h in handles {
                let (c, posts) = h.join().unwrap();
                correct += c;
                all.extend(posts);
            }
            let elapsed = t0.elapsed();
            let served = per_client * clients;
            let stats = router.stats();
            let m = &stats.per_model[0].1;
            println!(
                "served {served} requests from {clients} clients in {elapsed:.2?} \
                 -> {:.0} req/s end-to-end",
                served as f64 / elapsed.as_secs_f64()
            );
            println!("  {}", m.summary());
            println!(
                "  argmax accuracy vs sampled ground truth: {:.3}",
                correct as f64 / served as f64
            );

            // -- numerical cross-checks --------------------------------------
            // (a) XLA posterior == pure-Rust scorer posterior.
            let reference = ReferenceScorer::new(net.clone(), meta.class_var, meta.batch);
            let sample_rows: Vec<Vec<u8>> =
                all.iter().take(64).map(|(a, _)| a.values.clone()).collect();
            let ref_posts = reference.score(&sample_rows)?;
            let mut max_dev: f64 = 0.0;
            for ((_, xla_post), ref_post) in all.iter().take(64).zip(&ref_posts) {
                for (x, r) in xla_post.iter().zip(ref_post) {
                    max_dev = max_dev.max((x - r).abs());
                }
            }
            println!("  max |XLA - rust-reference| over 64 posteriors: {max_dev:.2e}");
            assert!(max_dev < 1e-4, "XLA scorer deviates from reference");

            // (b) Scorer posterior == exact junction-tree posterior (full
            //     evidence makes them mathematically identical).
            let jt = JunctionTree::build(&net);
            let mut engine = jt.engine();
            let mut max_dev_jt: f64 = 0.0;
            for (a, xla_post) in all.iter().take(16) {
                let ev: Evidence = (0..net.n_vars())
                    .filter(|&v| v != meta.class_var)
                    .map(|v| (v, a.get(v)))
                    .collect();
                let exact = engine.query(meta.class_var, &ev);
                for (x, e) in xla_post.iter().zip(&exact) {
                    max_dev_jt = max_dev_jt.max((x - e).abs());
                }
            }
            println!("  max |XLA - junction tree| over 16 posteriors: {max_dev_jt:.2e}");
            assert!(max_dev_jt < 1e-3, "XLA scorer deviates from exact inference");

            report.push_str(&format!(
                "{name}: {:.0} req/s e2e, exec {:.0} req/s, p95 {}µs, acc {:.3}, dev(ref) {max_dev:.1e}, dev(jt) {max_dev_jt:.1e}\n",
                served as f64 / elapsed.as_secs_f64(),
                m.exec_throughput(),
                m.latency_percentile_us(95.0),
                correct as f64 / served as f64,
            ));
        }
        println!("\n== xla classify summary ==\n{report}");
        Ok(())
    }

    /// The exported artifacts use bronc for asia and the last topo node
    /// for synthetic networks; recompute the same rule.
    fn class_var_of(net: &fastpgm::network::BayesianNetwork) -> usize {
        if let Some(v) = net.var_index("bronc") {
            v
        } else {
            *net.topological_order().last().unwrap()
        }
    }
}
