//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled XLA artifact (L1 Pallas kernel inside the L2
//! JAX classify graph, lowered at build time), serves a concurrent stream
//! of classification requests through the L3 coordinator (router + dynamic
//! batcher + PJRT executor), and cross-checks every returned posterior
//! against both the pure-Rust scorer and exact junction-tree inference.
//! Reports latency/throughput and writes the numbers EXPERIMENTS.md §E2E
//! records.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example e2e_serving [-- --requests 4096 --clients 8]`

use fastpgm::cli::Args;
use fastpgm::classify::argmax;
use fastpgm::coordinator::{BatcherConfig, Router};
use fastpgm::core::Evidence;
use fastpgm::inference::exact::JunctionTree;
use fastpgm::inference::InferenceEngine;
use fastpgm::io::fpgm;
use fastpgm::rng::Pcg;
use fastpgm::runtime::{ArtifactBundle, BatchScorer, ReferenceScorer, Scorer};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.parse_flag("requests", 4096usize);
    let clients = args.parse_flag("clients", 8usize);
    let artifacts = Path::new("artifacts");

    let mut report = String::new();
    for name in ["asia", "child_like", "alarm_like"] {
        let bundle = match ArtifactBundle::locate(artifacts, name) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping {name}: {e} (run `make artifacts`)");
                continue;
            }
        };
        let meta = bundle.read_meta()?;
        let net = fpgm::load(&bundle.fpgm)?;
        println!(
            "\n=== {name}: {} vars, class={} ({} states), batch={} ===",
            meta.n_vars,
            net.variable(meta.class_var).name,
            meta.n_classes,
            meta.batch
        );

        // -- L3 coordinator over the L1/L2 XLA artifact ------------------
        let mut router = Router::new();
        let b2 = bundle.clone();
        router.register_with(
            name,
            Box::new(move || Ok(Box::new(BatchScorer::load(&b2)?) as _)),
            BatcherConfig { max_batch: meta.batch, max_wait: Duration::from_millis(1) },
        )?;

        // -- concurrent request stream ----------------------------------
        let router = Arc::new(router);
        let net_arc = Arc::new(net.clone());
        let t0 = Instant::now();
        let per_client = requests / clients;
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let router = Arc::clone(&router);
                let net = Arc::clone(&net_arc);
                let name = name.to_string();
                std::thread::spawn(move || {
                    let mut rng = Pcg::seed_from(1000 + c as u64);
                    let mut correct = 0usize;
                    let mut posts = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let a = fastpgm::sampling::forward_sample(&net, &mut rng);
                        let post = router.classify(&name, a.values.clone()).unwrap();
                        if argmax(&post) == a.get(net.var_index_class()) {
                            correct += 1;
                        }
                        posts.push((a, post));
                    }
                    (correct, posts)
                })
            })
            .collect();
        let mut correct = 0usize;
        let mut all: Vec<(fastpgm::core::Assignment, Vec<f64>)> = Vec::new();
        for h in handles {
            let (c, posts) = h.join().unwrap();
            correct += c;
            all.extend(posts);
        }
        let elapsed = t0.elapsed();
        let served = per_client * clients;
        let stats = router.stats();
        let m = &stats.per_model[0].1;
        println!(
            "served {served} requests from {clients} clients in {elapsed:.2?} \
             -> {:.0} req/s end-to-end",
            served as f64 / elapsed.as_secs_f64()
        );
        println!("  {}", m.summary());
        println!(
            "  argmax accuracy vs sampled ground truth: {:.3}",
            correct as f64 / served as f64
        );

        // -- numerical cross-checks --------------------------------------
        // (a) XLA posterior == pure-Rust scorer posterior.
        let reference = ReferenceScorer::new(net.clone(), meta.class_var, meta.batch);
        let sample_rows: Vec<Vec<u8>> =
            all.iter().take(64).map(|(a, _)| a.values.clone()).collect();
        let ref_posts = reference.score(&sample_rows)?;
        let mut max_dev: f64 = 0.0;
        for ((_, xla_post), ref_post) in all.iter().take(64).zip(&ref_posts) {
            for (x, r) in xla_post.iter().zip(ref_post) {
                max_dev = max_dev.max((x - r).abs());
            }
        }
        println!("  max |XLA - rust-reference| over 64 posteriors: {max_dev:.2e}");
        assert!(max_dev < 1e-4, "XLA scorer deviates from reference");

        // (b) Scorer posterior == exact junction-tree posterior (full
        //     evidence makes them mathematically identical).
        let jt = JunctionTree::build(&net);
        let mut engine = jt.engine();
        let mut max_dev_jt: f64 = 0.0;
        for (a, xla_post) in all.iter().take(16) {
            let ev: Evidence = (0..net.n_vars())
                .filter(|&v| v != meta.class_var)
                .map(|v| (v, a.get(v)))
                .collect();
            let exact = engine.query(meta.class_var, &ev);
            for (x, e) in xla_post.iter().zip(&exact) {
                max_dev_jt = max_dev_jt.max((x - e).abs());
            }
        }
        println!("  max |XLA - junction tree| over 16 posteriors: {max_dev_jt:.2e}");
        assert!(max_dev_jt < 1e-3, "XLA scorer deviates from exact inference");

        report.push_str(&format!(
            "{name}: {:.0} req/s e2e, exec {:.0} req/s, p95 {}µs, acc {:.3}, dev(ref) {max_dev:.1e}, dev(jt) {max_dev_jt:.1e}\n",
            served as f64 / elapsed.as_secs_f64(),
            m.exec_throughput(),
            m.latency_percentile_us(95.0),
            correct as f64 / served as f64,
        ));
    }
    println!("\n== summary ==\n{report}");
    println!("e2e_serving OK");
    Ok(())
}

/// Helper trait so the closure above can fetch the class var without
/// capturing meta.
trait ClassVarExt {
    fn var_index_class(&self) -> usize;
}

impl ClassVarExt for fastpgm::network::BayesianNetwork {
    fn var_index_class(&self) -> usize {
        // The exported artifacts use bronc for asia and the last topo node
        // for synthetic networks; recompute the same rule.
        if let Some(v) = self.var_index("bronc") {
            v
        } else {
            *self.topological_order().last().unwrap()
        }
    }
}
