//! Binary image denoising with a grid Markov random field — the
//! computer-vision workload class the paper's introduction motivates for
//! Markov networks.
//!
//! A ground-truth binary image is corrupted with i.i.d. pixel flips; a
//! 4-connected Potts MRF (unary = observation likelihood, pairwise =
//! smoothness) is then decoded with loopy BP and with Gibbs sampling, and
//! both are compared against the noisy input on pixel accuracy.
//!
//! Run: `cargo run --release --example mrf_denoise`

use fastpgm::core::Evidence;
use fastpgm::mrf::gibbs::{gibbs_marginals, MrfGibbsOptions};
use fastpgm::mrf::lbp::{run_lbp, MrfLbpOptions};
use fastpgm::mrf::FactorGraph;
use fastpgm::rng::Pcg;

const ROWS: usize = 20;
const COLS: usize = 36;

/// Ground truth: "FP" glyphs on a dark background.
fn truth_image() -> Vec<u8> {
    let mut img = vec![0u8; ROWS * COLS];
    let mut set = |r: usize, c: usize| img[r * COLS + c] = 1;
    // F
    for r in 3..17 {
        set(r, 6);
        set(r, 7);
    }
    for c in 6..15 {
        set(3, c);
        set(4, c);
    }
    for c in 6..12 {
        set(9, c);
        set(10, c);
    }
    // P
    for r in 3..17 {
        set(r, 20);
        set(r, 21);
    }
    for c in 20..28 {
        set(3, c);
        set(4, c);
        set(9, c);
        set(10, c);
    }
    for r in 4..10 {
        set(r, 27);
        set(r, 26);
    }
    img
}

fn render(img: &[u8]) -> String {
    let mut out = String::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            out.push(if img[r * COLS + c] == 1 { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

fn accuracy(a: &[u8], b: &[u8]) -> f64 {
    let same = a.iter().zip(b).filter(|(x, y)| x == y).count();
    same as f64 / a.len() as f64
}

fn main() {
    let flip_p = 0.12;
    let truth = truth_image();
    let mut rng = Pcg::seed_from(2025);
    let noisy: Vec<u8> = truth
        .iter()
        .map(|&px| if rng.bool_with(flip_p) { 1 - px } else { px })
        .collect();

    println!("ground truth:\n{}", render(&truth));
    println!(
        "noisy observation ({}% flips, accuracy {:.3}):\n{}",
        (flip_p * 100.0) as u32,
        accuracy(&noisy, &truth),
        render(&noisy)
    );

    // Unary: likelihood of the observed pixel given the latent one.
    let stay = 1.0 - flip_p;
    let fg = FactorGraph::grid(ROWS, COLS, 2, 1.4, |r, c| {
        let obs = noisy[r * COLS + c];
        if obs == 1 { vec![flip_p, stay] } else { vec![stay, flip_p] }
    });

    // -- loopy BP decode -------------------------------------------------
    let t0 = std::time::Instant::now();
    let lbp = run_lbp(&fg, &Evidence::new(), &MrfLbpOptions::default());
    let lbp_img: Vec<u8> = lbp.decode().into_iter().map(|s| s as u8).collect();
    let lbp_acc = accuracy(&lbp_img, &truth);
    println!(
        "loopy BP decode ({} iters, converged={}, {:.1?}, accuracy {:.3}):\n{}",
        lbp.iterations,
        lbp.converged,
        t0.elapsed(),
        lbp_acc,
        render(&lbp_img)
    );

    // -- Gibbs decode ----------------------------------------------------
    let t0 = std::time::Instant::now();
    let opts = MrfGibbsOptions { sweeps: 600, burn_in: 100, ..Default::default() };
    let marg = gibbs_marginals(&fg, &Evidence::new(), &opts);
    let gibbs_img: Vec<u8> = marg
        .iter()
        .map(|p| u8::from(p[1] > 0.5))
        .collect();
    let gibbs_acc = accuracy(&gibbs_img, &truth);
    println!(
        "Gibbs decode ({} sweeps, {:.1?}, accuracy {:.3}):\n{}",
        opts.sweeps,
        t0.elapsed(),
        gibbs_acc,
        render(&gibbs_img)
    );

    assert!(
        lbp_acc > accuracy(&noisy, &truth) + 0.03,
        "MRF smoothing must beat the raw noisy image"
    );
    assert!(gibbs_acc > accuracy(&noisy, &truth));
    println!("mrf_denoise OK (LBP {lbp_acc:.3}, Gibbs {gibbs_acc:.3})");
}
