//! Medical-diagnosis scenario — the workload class the paper's intro
//! motivates (biomedical informatics): train a Bayesian-network classifier
//! to predict a disease variable from observable symptoms, compare
//! structure sources, and inspect per-case posteriors.
//!
//! Run: `cargo run --release --example diagnosis`

use fastpgm::classify::{argmax, BnClassifier, StructureSource};
use fastpgm::network::repository;
use fastpgm::parameter::MleOptions;
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::PcOptions;

fn main() {
    // "Patients": samples from ASIA; the diagnostic target is bronchitis.
    let world = repository::asia();
    let class_var = world.var_index("bronc").unwrap();
    let mut rng = Pcg::seed_from(77);
    let records = forward_sample_dataset(&world, 12_000, &mut rng);
    let (train, test) = records.split(0.75);
    println!(
        "{} training cases, {} held-out cases; target = {}",
        train.n_rows(),
        test.n_rows(),
        world.variable(class_var).name
    );

    for (label, source) in [
        ("naive Bayes", StructureSource::NaiveBayes),
        ("true structure", StructureSource::Fixed(world.dag().clone())),
        (
            "PC-stable learned",
            StructureSource::Learn(PcOptions {
                threads: fastpgm::parallel::default_threads(),
                ..Default::default()
            }),
        ),
    ] {
        let t0 = std::time::Instant::now();
        let clf = BnClassifier::train(&train, class_var, source, &MleOptions::default());
        let acc = clf.evaluate(&test);
        println!(
            "  {label:<18} accuracy {:.3}  (trained in {:.2?}, {} params)",
            acc,
            t0.elapsed(),
            clf.net.n_parameters()
        );
    }

    // Posterior for one concrete patient: smoker with positive x-ray and
    // dyspnoea, no Asia trip.
    let clf = BnClassifier::train(
        &train,
        class_var,
        StructureSource::Fixed(world.dag().clone()),
        &MleOptions::default(),
    );
    let patient = {
        let mut row = vec![0u8; world.n_vars()];
        row[world.var_index("smoke").unwrap()] = 1;
        row[world.var_index("xray").unwrap()] = 1;
        row[world.var_index("dysp").unwrap()] = 1;
        row
    };
    let post = clf.posterior(&patient);
    println!(
        "patient (smoker, xray+, dysp+): P(bronc) = {:.3} -> {}",
        post[1],
        world.variable(class_var).state_name(argmax(&post))
    );
    println!("diagnosis OK");
}
