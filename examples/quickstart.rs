//! Quickstart: the full Fast-PGM pipeline from Figure 1 on one page.
//!
//! 1. take a known network (SURVEY) and draw training data from it,
//! 2. recover the structure with PC-stable,
//! 3. fit the parameters with MLE,
//! 4. answer posterior queries exactly (junction tree) and approximately
//!    (likelihood weighting),
//! 5. measure learning quality (SHD) and inference quality (Hellinger).
//!
//! SURVEY (Scutari) is the canonical *faithful* learning target; ASIA's
//! deterministic `either` node violates faithfulness, so PC provably
//! cannot recover its xray/dysp edges — see `examples/diagnosis.rs` for
//! the asia-based inference workload.
//!
//! Run: `cargo run --release --example quickstart`

use fastpgm::core::Evidence;
use fastpgm::inference::approx::{ApproxOptions, LikelihoodWeighting};
use fastpgm::inference::exact::JunctionTree;
use fastpgm::inference::InferenceEngine;
use fastpgm::metrics;
use fastpgm::network::repository;
use fastpgm::parameter::{mle, MleOptions};
use fastpgm::rng::Pcg;
use fastpgm::sampling::forward_sample_dataset;
use fastpgm::structure::{pc_stable_parallel, PcOptions};

fn main() {
    // -- data ---------------------------------------------------------
    let truth = repository::survey();
    let mut rng = Pcg::seed_from(2024);
    let data = forward_sample_dataset(&truth, 50_000, &mut rng);
    println!("sampled {} rows from {}", data.n_rows(), truth.name());

    // -- structure learning -------------------------------------------
    let opts = PcOptions {
        alpha: 0.05,
        threads: fastpgm::parallel::default_threads(),
        ..Default::default()
    };
    let learned = pc_stable_parallel(&data, &opts);
    let shd = metrics::shd_vs_dag_cpdag(&learned.graph, truth.dag());
    let (prec, rec, f1) = metrics::skeleton_prf(&learned.graph, truth.dag());
    println!(
        "PC-stable: {} edges with {} CI tests; SHD vs true CPDAG = {shd}, \
         skeleton P/R/F1 = {prec:.2}/{rec:.2}/{f1:.2}",
        learned.n_edges(),
        learned.n_tests
    );
    assert!(rec >= 0.8, "skeleton mostly recovered");

    // -- parameter learning --------------------------------------------
    let dag = learned
        .graph
        .to_dag()
        .unwrap_or_else(|| truth.dag().clone());
    let model = mle(&data, &dag, &MleOptions::default());
    println!("MLE fitted {} parameters", model.n_parameters());

    // -- exact inference ------------------------------------------------
    let ev = Evidence::new()
        .with(truth.var_index("age").unwrap(), 0) // young
        .with(truth.var_index("occ").unwrap(), 0); // employed
    let jt = JunctionTree::build(&model);
    let mut exact = jt.engine();
    let travel = truth.var_index("travel").unwrap();
    let p_exact = exact.query(travel, &ev);
    println!("P(travel | age=young, occ=emp)  junction-tree: {p_exact:?}");

    // -- approximate inference -------------------------------------------
    let mut lw = LikelihoodWeighting::new(
        &model,
        ApproxOptions { n_samples: 50_000, ..Default::default() },
    );
    let p_lw = lw.query(travel, &ev);
    let h = metrics::hellinger(&p_exact, &p_lw);
    println!("P(travel | ...)        likelihood-weighting: {p_lw:?} (Hellinger {h:.4})");
    assert!(h < 0.05, "sampler agrees with exact engine");

    // -- ground truth check ----------------------------------------------
    let p_true = truth.brute_force_posterior(travel, &ev);
    let h_true = metrics::hellinger(&p_exact, &p_true);
    println!("P(travel | ...)   true network, brute force: {p_true:?} (Hellinger {h_true:.4})");
    assert!(h_true < 0.05, "learned model close to truth");
    println!("quickstart OK");
}
