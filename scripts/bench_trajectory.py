#!/usr/bin/env python3
"""Collate the per-commit BENCH_*.json artifacts into one trajectory table.

Every bench target in this repo writes a BENCH_<name>.json with a
top-level ``bench`` tag, a ``config`` block, and a ``scenarios`` array of
flat objects. This script walks whatever BENCH_*.json files are present
(a fresh checkout after ``cargo bench``, or an unpacked CI artifact
directory) and prints one aligned row per scenario, so a perf trajectory
across commits is a diff of two runs of this script.

Zero dependencies — stdlib only. Usage:

    python3 scripts/bench_trajectory.py [dir-with-BENCH-json]   # default .
    python3 scripts/bench_trajectory.py --json                  # machine-readable
"""

import glob
import json
import os
import sys

# Keys promoted into the table when a scenario carries them, in display
# order. Everything else stays visible via --json.
COLUMNS = [
    ("net", "{}"),
    ("mode", "{}"),
    ("level", "{}"),
    ("algo", "{}"),
    ("queries", "{:.0f}"),
    ("throughput_qps", "{:.0f}"),
    ("p50_us", "{:.1f}"),
    ("p99_us", "{:.1f}"),
    ("speedup_vs_rebuild", "{:.2f}x"),
    ("cache_hit_rate", "{:.3f}"),
    ("overhead_vs_off", "{:+.1%}"),
]


def load(path):
    with open(path) as f:
        return json.load(f)


def scenario_row(bench, scenario):
    row = {"bench": bench}
    for key, fmt in COLUMNS:
        if key in scenario:
            value = scenario[key]
            try:
                row[key] = fmt.format(value)
            except (ValueError, TypeError):
                row[key] = str(value)
    return row


def main():
    args = [a for a in sys.argv[1:] if a != "--json"]
    as_json = "--json" in sys.argv[1:]
    root = args[0] if args else "."
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        print(f"no BENCH_*.json under {root!r} — run `cargo bench` first",
              file=sys.stderr)
        return 1

    rows = []
    gates = []
    for path in paths:
        try:
            doc = load(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"skipping {path}: {e}", file=sys.stderr)
            continue
        bench = doc.get("bench", os.path.basename(path))
        for scenario in doc.get("scenarios", []):
            rows.append(scenario_row(bench, scenario))
        if "full_overhead_vs_off" in doc:
            gates.append(
                ("obs full-span overhead", doc["full_overhead_vs_off"], 0.05))

    if as_json:
        print(json.dumps(rows, indent=2))
        return 0

    keys = ["bench"] + [k for k, _ in COLUMNS if any(k in r for r in rows)]
    widths = {
        k: max([len(k)] + [len(r.get(k, "")) for r in rows]) for k in keys
    }
    header = "  ".join(k.ljust(widths[k]) for k in keys)
    print(header)
    print("-" * len(header))
    for r in rows:
        print("  ".join(r.get(k, "-").ljust(widths[k]) for k in keys))

    for label, value, limit in gates:
        status = "OK" if value < limit else "OVER"
        print(f"\ngate: {label} {value:+.1%} (limit {limit:.0%}) [{status}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
